//! Differential kernel-parity harness (DESIGN.md §Compute-Kernels).
//!
//! Two tolerance regimes, deliberately distinct:
//!
//! * **bit-exact (`==`)** — within-arm identities (serial ≡ parallel,
//!   gemv ≡ batched-row, fused panel ≡ rowwise), the blocked-vs-naive pin
//!   on the *scalar* arm, and the integer-domain fused GEMM against the
//!   f32 rowwise oracle (i32 accumulation is associative; inside the f32
//!   exactness window both paths hold the same number);
//! * **ULP-bounded** — the AVX2 arm against the scalar oracles: FMA fuses
//!   each multiply-add into one rounding, so cross-arm f32 results differ
//!   by a few last-place bits.  The budget is `≤ 16` ULP with an absolute
//!   escape hatch `4·k·ε·(1 + Σ|aₜ·bₜ|)` for catastrophic cancellation.
//!
//! `verify.sh` runs this file **three times** as its kernel smoke gate:
//! once with `FLEXROUND_FORCE_SCALAR=1` (scalar tiles only), once with
//! `FLEXROUND_FORCE_NO_MADD=1` (AVX2 f32/i32 kernels, i16-madd pinned
//! off), and once fully auto-detected (madd enabled where eligible).  The
//! per-arm tests below additionally pin *both* arms inside a single
//! process via `Dispatch::with_isa`, and the madd tests force the route
//! through `IntRoute` regardless of the env knobs — so even the
//! forced-scalar run exercises the SIMD arm's identities when the
//! hardware supports it (`Isa::detect()` ignores the env override).

use flexround::infer::kernels::{
    gemm_fused, gemm_fused_int, gemm_fused_int_route, gemm_fused_int_with, gemm_fused_rowwise,
    gemm_fused_rowwise_isa, gemm_fused_with, gemm_ref, int_gemm_eligible, int_safe_k, IntRoute,
};
use flexround::infer::PackedMatrix;
use flexround::linalg::{self, simd, Dispatch, Isa, PAR_FLOPS_MIN};
use flexround::tensor::{qrange, Tensor};
use flexround::util::prop::Prop;
use flexround::util::rng::Pcg32;
use flexround::util::ulp::ulp_diff;

fn randt(rng: &mut Pcg32, rows: usize, cols: usize) -> Tensor {
    Tensor::from_f32((0..rows * cols).map(|_| rng.next_normal()).collect(), &[rows, cols])
        .expect("random tensor")
}

fn random_packed(rng: &mut Pcg32, rows: usize, cols: usize, bits: u32) -> PackedMatrix {
    let (qmin, qmax) = qrange(bits, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
    let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
    let zp: Vec<f32> = (0..rows).map(|_| rng.below(3) as f32 - 1.0).collect();
    PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).expect("pack")
}

/// Packed matrix with explicit control over grid symmetry and zero-points:
/// `zero_zp` pins every row's zero-point to 0; otherwise each row gets a
/// nonzero (sometimes fractional) zero-point strictly inside the grid.
fn random_packed_zp(
    rng: &mut Pcg32,
    rows: usize,
    cols: usize,
    bits: u32,
    symmetric: bool,
    zero_zp: bool,
) -> PackedMatrix {
    let (qmin, qmax) = qrange(bits, symmetric);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    let span = (qmax - qmin + 1) as u32;
    let mut codes: Vec<i32> = (0..rows * cols).map(|_| qmin + rng.below(span) as i32).collect();
    // grid edges in every matrix
    codes[0] = qmin;
    let n = codes.len();
    codes[n - 1] = qmax;
    let scale: Vec<f32> = (0..rows).map(|_| 0.02 + 0.3 * rng.next_f32()).collect();
    let zp: Vec<f32> = (0..rows)
        .map(|_| {
            if zero_zp {
                0.0
            } else {
                // nonzero, sometimes fractional — the epilogue is f32 on
                // both paths, so bit-exactness must not depend on zp ∈ ℤ
                1.0 + rng.below(span.saturating_sub(1).max(1)) as f32 + 0.5 * rng.next_f32()
            }
        })
        .collect();
    PackedMatrix::pack(&codes, rows, cols, bits, qmin, scale, zp).expect("pack")
}

/// The cross-arm tolerance criterion: equal bits, a small ULP distance, or
/// the cancellation escape hatch scaled by the element's magnitude bound
/// `mag = Σ_t |aₜ·bₜ|` (computed by running the naive oracle on |inputs|).
fn check_close(
    label: &str,
    got: &[f32],
    want: &[f32],
    k: usize,
    mags: &[f32],
) -> Result<(), String> {
    assert_eq!(got.len(), want.len());
    for (i, ((&g, &w), &mag)) in got.iter().zip(want).zip(mags).enumerate() {
        let ok = g == w
            || ulp_diff(g, w) <= 16
            || (g - w).abs() <= 4.0 * (k.max(1) as f32) * f32::EPSILON * (1.0 + mag);
        if !ok {
            return Err(format!(
                "{label}: element {i} diverged: simd {g} vs scalar {w} ({} ulp, k={k})",
                ulp_diff(g, w)
            ));
        }
    }
    Ok(())
}

#[test]
fn blocked_gemms_match_naive_oracles_bitwise() {
    // random dims 1..=40 deliberately straddle the 4×8 tile in every way:
    // full tiles, ragged row edges, ragged column edges, sub-tile problems.
    // Exact `==` is a *scalar-arm* pin — the SIMD arm is held to the same
    // oracles under the ULP budget in the sweep test below.
    let scalar = Dispatch::serial().with_isa(Isa::Scalar);
    Prop::new("linalg::gemm_* ≡ naive oracles (scalar arm)").cases(120).check(|rng| {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(40) as usize;
        let r = 1 + rng.below(40) as usize;
        let a = randt(rng, m, k);
        let bt = randt(rng, r, k);
        let nt = a.matmul_nt_with(&bt, &scalar).map_err(|e| e.to_string())?;
        let nt_ref = linalg::gemm_nt_ref(
            a.as_f32().map_err(|e| e.to_string())?,
            bt.as_f32().map_err(|e| e.to_string())?,
            m,
            k,
            r,
        );
        if nt.as_f32().map_err(|e| e.to_string())? != nt_ref.as_slice() {
            return Err(format!("NT {m}×{k}·({r}×{k})ᵀ drifted from the naive oracle"));
        }
        let bn = randt(rng, k, r);
        let nn = a.matmul_nn_with(&bn, &scalar).map_err(|e| e.to_string())?;
        let nn_ref = linalg::gemm_nn_ref(
            a.as_f32().map_err(|e| e.to_string())?,
            bn.as_f32().map_err(|e| e.to_string())?,
            m,
            k,
            r,
        );
        if nn.as_f32().map_err(|e| e.to_string())? != nn_ref.as_slice() {
            return Err(format!("NN {m}×{k}·{k}×{r} drifted from the naive oracle"));
        }
        let at = randt(rng, k, m);
        let tn = at.matmul_tn_with(&bn, &scalar).map_err(|e| e.to_string())?;
        let tn_ref = linalg::gemm_tn_ref(
            at.as_f32().map_err(|e| e.to_string())?,
            bn.as_f32().map_err(|e| e.to_string())?,
            k,
            m,
            r,
        );
        if tn.as_f32().map_err(|e| e.to_string())? != tn_ref.as_slice() {
            return Err(format!("TN ({k}×{m})ᵀ·{k}×{r} drifted from the naive oracle"));
        }
        Ok(())
    });
}

#[test]
fn simd_tiles_match_scalar_oracles_within_ulp_budget() {
    // The tentpole's differential sweep: every SIMD kernel family against
    // the scalar tiles over adversarial shapes — tile-edge dims, k = 0,
    // single rows, K off the 8-lane width in both directions.  On hardware
    // without AVX2 both arms are the scalar tiles and every comparison is
    // trivially equal — the sweep still runs, it just cannot fail.
    const EDGE: [usize; 14] = [1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33];
    const KS: [usize; 9] = [0, 1, 7, 8, 9, 15, 16, 17, 33];
    let vec_isa = Isa::detect();
    let scalar = Dispatch::serial().with_isa(Isa::Scalar);
    let vectored = Dispatch::serial().with_isa(vec_isa);
    Prop::new("simd ≡ scalar under the ULP budget").cases(80).check(|rng| {
        let m = EDGE[rng.below(EDGE.len() as u32) as usize];
        let r = EDGE[rng.below(EDGE.len() as u32) as usize];
        let k = if rng.below(2) == 0 {
            KS[rng.below(KS.len() as u32) as usize]
        } else {
            1 + rng.below(48) as usize
        };
        let a: Vec<f32> = (0..m * k).map(|_| rng.next_normal()).collect();
        let bt: Vec<f32> = (0..r * k).map(|_| rng.next_normal()).collect();
        let bn: Vec<f32> = (0..k * r).map(|_| rng.next_normal()).collect();
        let at: Vec<f32> = (0..k * m).map(|_| rng.next_normal()).collect();
        let aa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
        let bta: Vec<f32> = bt.iter().map(|v| v.abs()).collect();
        let bna: Vec<f32> = bn.iter().map(|v| v.abs()).collect();
        let ata: Vec<f32> = at.iter().map(|v| v.abs()).collect();
        // NT / NN / TN tile families
        check_close(
            "NT",
            &linalg::gemm_nt(&a, &bt, m, k, r, &vectored),
            &linalg::gemm_nt(&a, &bt, m, k, r, &scalar),
            k,
            &linalg::gemm_nt_ref(&aa, &bta, m, k, r),
        )?;
        check_close(
            "NN",
            &linalg::gemm_nn(&a, &bn, m, k, r, &vectored),
            &linalg::gemm_nn(&a, &bn, m, k, r, &scalar),
            k,
            &linalg::gemm_nn_ref(&aa, &bna, m, k, r),
        )?;
        check_close(
            "TN",
            &linalg::gemm_tn(&at, &bn, k, m, r, &vectored),
            &linalg::gemm_tn(&at, &bn, k, m, r, &scalar),
            k,
            &linalg::gemm_tn_ref(&ata, &bna, k, m, r),
        )?;
        // single-row fast paths and the shared dot core
        let x: Vec<f32> = (0..k).map(|_| rng.next_normal()).collect();
        let xa: Vec<f32> = x.iter().map(|v| v.abs()).collect();
        let mut gs = vec![0.0f32; r];
        let mut gv = vec![0.0f32; r];
        simd::gemv_nt(Isa::Scalar, &x, &bt, k, r, &mut gs);
        simd::gemv_nt(vec_isa, &x, &bt, k, r, &mut gv);
        check_close("gemv_nt", &gv, &gs, k, &linalg::gemm_nt_ref(&xa, &bta, 1, k, r))?;
        let mut gs = vec![0.0f32; r];
        let mut gv = vec![0.0f32; r];
        simd::gemv_nn(Isa::Scalar, &x, &bn, k, r, &mut gs);
        simd::gemv_nn(vec_isa, &x, &bn, k, r, &mut gv);
        check_close("gemv_nn", &gv, &gs, k, &linalg::gemm_nn_ref(&xa, &bna, 1, k, r))?;
        let y: Vec<f32> = (0..k).map(|_| rng.next_normal()).collect();
        let ya: Vec<f32> = y.iter().map(|v| v.abs()).collect();
        check_close(
            "dot",
            &[simd::dot(vec_isa, &x, &y)],
            &[simd::dot(Isa::Scalar, &x, &y)],
            k,
            &linalg::gemm_nt_ref(&xa, &ya, 1, k, 1),
        )?;
        Ok(())
    });
}

#[test]
fn serial_and_parallel_dispatch_are_bit_identical() {
    // parameterized over the forced-scalar AND detected-SIMD arms: the
    // panel split must never change an element's reduction tree on either
    Prop::new("linalg serial ≡ parallel, both arms").cases(12).check(|rng| {
        // dims chosen to clear the flops threshold so the pool actually
        // fans out, with ragged edges to cross panel boundaries mid-tile
        let m = 42 + rng.below(23) as usize;
        let k = 42 + rng.below(23) as usize;
        let r = 42 + rng.below(23) as usize;
        assert!(m * k * r >= PAR_FLOPS_MIN, "{m}·{k}·{r} must clear the dispatch threshold");
        let a = randt(rng, m, k);
        let bt = randt(rng, r, k);
        let bn = randt(rng, k, r);
        let at = randt(rng, k, m);
        for isa in [Isa::Scalar, Isa::detect()] {
            let serial = Dispatch::serial().with_isa(isa);
            let s = a.matmul_nt_with(&bt, &serial).map_err(|e| e.to_string())?;
            let p = a
                .matmul_nt_with(&bt, &Dispatch::new(4).with_isa(isa))
                .map_err(|e| e.to_string())?;
            if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
                return Err(format!("NT serial vs parallel drift at {m}×{k}×{r} ({})", isa.label()));
            }
            let s = a.matmul_nn_with(&bn, &serial).map_err(|e| e.to_string())?;
            let p = a
                .matmul_nn_with(&bn, &Dispatch::new(3).with_isa(isa))
                .map_err(|e| e.to_string())?;
            if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
                return Err(format!("NN serial vs parallel drift at {m}×{k}×{r} ({})", isa.label()));
            }
            let s = at.matmul_tn_with(&bn, &serial).map_err(|e| e.to_string())?;
            let p = at
                .matmul_tn_with(&bn, &Dispatch::new(5).with_isa(isa))
                .map_err(|e| e.to_string())?;
            if s.as_f32().map_err(|e| e.to_string())? != p.as_f32().map_err(|e| e.to_string())? {
                return Err(format!("TN serial vs parallel drift at {m}×{k}×{r} ({})", isa.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn k_zero_contractions_are_well_defined_zeros() {
    // a (3, 0)·(5, 0)ᵀ contraction is empty: the answer is all zeros, not
    // an error or garbage — tile edges must tolerate empty k slices
    let a = Tensor::from_f32(vec![], &[3, 0]).unwrap();
    let b = Tensor::from_f32(vec![], &[5, 0]).unwrap();
    let nt = a.matmul_nt(&b).unwrap();
    assert_eq!(nt.shape(), &[3, 5]);
    assert_eq!(nt.as_f32().unwrap(), &[0.0; 15]);
    // NN with an empty inner axis, TN with zero shared rows
    let bn = Tensor::from_f32(vec![], &[0, 4]).unwrap();
    let nn = a.matmul_nn(&bn).unwrap();
    assert_eq!(nn.shape(), &[3, 4]);
    assert_eq!(nn.as_f32().unwrap(), &[0.0; 12]);
    let at = Tensor::from_f32(vec![], &[0, 2]).unwrap();
    let tn = at.matmul_tn(&bn).unwrap();
    assert_eq!(tn.shape(), &[2, 4]);
    assert_eq!(tn.as_f32().unwrap(), &[0.0; 8]);
    // zero-row B: a (3, k)·(0, k)ᵀ product is a (3, 0) tensor
    let a2 = Tensor::from_f32(vec![1.0; 6], &[3, 2]).unwrap();
    let b0 = Tensor::from_f32(vec![], &[0, 2]).unwrap();
    assert_eq!(a2.matmul_nt(&b0).unwrap().shape(), &[3, 0]);
}

#[test]
fn batch1_rows_take_the_gemv_path_with_identical_bits() {
    // parameterized over both arms — this was the latent gap: the old test
    // only ever pinned whatever arm happened to be active
    Prop::new("gemv dispatch ≡ batched rows, both arms").cases(24).check(|rng| {
        let k = 1 + rng.below(50) as usize;
        let r = 1 + rng.below(30) as usize;
        let n = 2 + rng.below(5) as usize;
        let x = randt(rng, n, k);
        let b = randt(rng, r, k);
        for isa in [Isa::Scalar, Isa::detect()] {
            let d = Dispatch::serial().with_isa(isa);
            let full = x.matmul_nt_with(&b, &d).map_err(|e| e.to_string())?;
            for i in 0..n {
                let row = x.slice_rows(i, i + 1).map_err(|e| e.to_string())?;
                // m == 1 dispatches to the gemv core inside gemm_nt
                let one = row.matmul_nt_with(&b, &d).map_err(|e| e.to_string())?;
                let fv = full.as_f32().map_err(|e| e.to_string())?;
                if one.as_f32().map_err(|e| e.to_string())? != &fv[i * r..(i + 1) * r] {
                    return Err(format!(
                        "gemv row {i} ≠ batched row ({n}×{k}·{r}ᵀ, {})",
                        isa.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn fused_panel_kernel_matches_oracles_at_all_packed_widths() {
    Prop::new("fused panel ≡ rowwise ≡ scalar ref, 2/3/4/8-bit, both arms").cases(32).check(
        |rng| {
            let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
            let rows = 1 + rng.below(24) as usize;
            let cols = 1 + rng.below(40) as usize;
            let n = 1 + rng.below(6) as usize;
            let m = random_packed(rng, rows, cols, bits);
            let x = randt(rng, n, cols);
            let reference = gemm_ref(&x, &m).map_err(|e| e.to_string())?;
            for isa in [Isa::Scalar, Isa::detect()] {
                let rowwise = gemm_fused_rowwise_isa(&x, &m, isa).map_err(|e| e.to_string())?;
                for workers in [1usize, 4] {
                    let d = Dispatch::new(workers).with_isa(isa);
                    let fused = gemm_fused_with(&x, &m, &d).map_err(|e| e.to_string())?;
                    // bit-exact against the rowwise kernel *on the same arm*
                    if fused.as_f32().map_err(|e| e.to_string())?
                        != rowwise.as_f32().map_err(|e| e.to_string())?
                    {
                        return Err(format!(
                            "panel(workers={workers}, {}) ≠ rowwise at {bits}-bit \
                             {rows}×{cols} batch {n}",
                            isa.label()
                        ));
                    }
                    // tolerance against the independent scalar reference
                    // (different algebraic form, so only ≤1e-4-close)
                    let d = fused.max_abs_diff(&reference).map_err(|e| e.to_string())?;
                    let tol = 1e-4 * (1.0 + reference.abs_max());
                    if d > tol {
                        return Err(format!(
                            "panel vs scalar ref: max|Δ| {d} > {tol} at {bits}-bit {rows}×{cols}"
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn fused_batch1_decode_path_is_bit_identical() {
    // the gemv fast path inside gemm_fused is what decode_step runs; its
    // bits must equal both the batched kernel's row and the rowwise oracle,
    // on whichever arm is pinned
    let mut rng = Pcg32::seeded(97);
    for bits in [2u32, 3, 4, 8] {
        let m = random_packed(&mut rng, 48, 31, bits);
        let batch = randt(&mut rng, 4, 31);
        for isa in [Isa::Scalar, Isa::detect()] {
            let d = Dispatch::serial().with_isa(isa);
            let full = gemm_fused_with(&batch, &m, &d).unwrap();
            for i in 0..4 {
                let row = batch.slice_rows(i, i + 1).unwrap();
                let one = gemm_fused_with(&row, &m, &d).unwrap();
                let oracle = gemm_fused_rowwise_isa(&row, &m, isa).unwrap();
                assert_eq!(
                    one.as_f32().unwrap(),
                    oracle.as_f32().unwrap(),
                    "{bits}-bit vs oracle ({})",
                    isa.label()
                );
                assert_eq!(
                    one.as_f32().unwrap(),
                    &full.as_f32().unwrap()[i * 48..(i + 1) * 48],
                    "{bits}-bit batch-1 row {i} vs batched ({})",
                    isa.label()
                );
            }
        }
    }
}

#[test]
fn fused_serial_parallel_bit_identity_holds() {
    // kernels.rs pinned this for the old kernel; re-pin per arm on the
    // panel kernel
    let mut rng = Pcg32::seeded(13);
    for bits in [4u32, 8] {
        let m = random_packed(&mut rng, 128, 96, bits);
        let x = randt(&mut rng, 16, 96);
        for isa in [Isa::Scalar, Isa::detect()] {
            let serial = gemm_fused_with(&x, &m, &Dispatch::serial().with_isa(isa)).unwrap();
            let par = gemm_fused_with(&x, &m, &Dispatch::new(4).with_isa(isa)).unwrap();
            assert_eq!(
                serial.as_f32().unwrap(),
                par.as_f32().unwrap(),
                "{bits}-bit ({})",
                isa.label()
            );
        }
    }
}

#[test]
fn integer_fused_gemm_is_bit_exact_at_all_widths() {
    // THE integer-domain acceptance pin: integral in-window activations at
    // 2/3/4/8 bits, symmetric and asymmetric grids, zero and nonzero
    // per-row zero-points, serial and parallel, both ISA arms — every
    // combination must reproduce the f32 rowwise oracle bit-for-bit, and
    // the integer result itself must be identical across arms.
    Prop::new("integer fused gemm ≡ rowwise, bitwise").cases(48).check(|rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let symmetric = rng.below(2) == 0;
        let zero_zp = rng.below(2) == 0;
        let rows = 1 + rng.below(20) as usize;
        let cols = 1 + rng.below(32) as usize;
        let n = 1 + rng.below(4) as usize;
        let m = random_packed_zp(rng, rows, cols, bits, symmetric, zero_zp);
        // activations: exact integers inside the f32 exactness window
        // (2²⁴ − 1) / (k · max|code|) — small enough to stay in-window at
        // every width, with sign coverage and zeros
        let amax = 20u32;
        let x = Tensor::from_f32(
            (0..n * cols).map(|_| rng.below(2 * amax + 1) as f32 - amax as f32).collect(),
            &[n, cols],
        )
        .map_err(|e| e.to_string())?;
        if !int_gemm_eligible(&x, &m) {
            return Err(format!("{bits}-bit integral batch should be int-eligible"));
        }
        let mut across_arms: Vec<Vec<f32>> = Vec::new();
        for isa in [Isa::Scalar, Isa::detect()] {
            let rowwise = gemm_fused_rowwise_isa(&x, &m, isa).map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let d = Dispatch::new(workers).with_isa(isa);
                let auto = gemm_fused_with(&x, &m, &d).map_err(|e| e.to_string())?;
                let explicit = gemm_fused_int_with(&x, &m, &d).map_err(|e| e.to_string())?;
                if auto.as_f32().map_err(|e| e.to_string())?
                    != rowwise.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!(
                        "integer auto-route ≠ rowwise ({bits}-bit, zp0={zero_zp}, \
                         sym={symmetric}, workers={workers}, {})",
                        isa.label()
                    ));
                }
                if explicit.as_f32().map_err(|e| e.to_string())?
                    != auto.as_f32().map_err(|e| e.to_string())?
                {
                    return Err(format!("gemm_fused_int ≠ auto route ({bits}-bit)"));
                }
                across_arms.push(auto.as_f32().map_err(|e| e.to_string())?.to_vec());
            }
        }
        // i32 accumulation is associative: the integer result may not vary
        // across arms or worker counts at all
        if !across_arms.windows(2).all(|w| w[0] == w[1]) {
            return Err(format!("integer result differs across arms/workers ({bits}-bit)"));
        }
        Ok(())
    });
    // a problem big enough that the parallel integer path genuinely fans
    // out (flops ≥ PAR_FLOPS_MIN, rows ≥ 2·workers)
    let mut rng = Pcg32::seeded(41);
    for bits in [4u32, 8] {
        let m = random_packed_zp(&mut rng, 128, 96, bits, false, false);
        let x = Tensor::from_f32(
            (0..16 * 96).map(|_| rng.below(41) as f32 - 20.0).collect(),
            &[16, 96],
        )
        .unwrap();
        assert!(16 * 128 * 96 >= PAR_FLOPS_MIN);
        assert!(int_gemm_eligible(&x, &m), "{bits}-bit big batch should be int-eligible");
        let rowwise = gemm_fused_rowwise(&x, &m).unwrap();
        let serial = gemm_fused(&x, &m, 1).unwrap();
        let par = gemm_fused(&x, &m, 4).unwrap();
        assert_eq!(serial.as_f32().unwrap(), rowwise.as_f32().unwrap(), "{bits}-bit serial");
        assert_eq!(serial.as_f32().unwrap(), par.as_f32().unwrap(), "{bits}-bit parallel");
    }
}

#[test]
fn i32_accumulator_overflow_guard_pins_safe_k() {
    // Pinned worst cases (the comment on int_safe_k documents both):
    // W8 asymmetric grid × 8-bit-magnitude activations → every practical
    // hidden width fits one i32 accumulator; adversarial 2²⁰ activations →
    // the widening fallback engages after just 8 terms.
    assert_eq!(int_safe_k(255, 127), 66_311);
    assert_eq!(int_safe_k(255, 1 << 20), 8);
    assert_eq!(int_safe_k(1, 1), i32::MAX as usize);
    // division pin: safe_k is exactly the largest count of worst-case
    // terms that cannot leave i32 range — one more term could
    Prop::new("int_safe_k is tight").cases(64).check(|rng| {
        let cm = 1 + rng.below(255) as i64;
        let am = 1 + rng.below(1 << 20) as i64;
        let per = cm * am;
        let sk = int_safe_k(cm, am) as i64;
        if sk * per > i32::MAX as i64 {
            return Err(format!("safe_k {sk} × per-term {per} can overflow i32"));
        }
        if (sk + 1) * per <= i32::MAX as i64 {
            return Err(format!("safe_k {sk} is not tight for per-term {per}"));
        }
        Ok(())
    });
    // end-to-end: adversarial codes at the bit-width range edges times
    // worst-case huge activations, K far beyond safe_k — the chunked
    // i64-widening path must reproduce an independent i64 reference
    // exactly, on both arms.  (act_mag per width is the largest power of
    // two under the explicit API's i32::MAX / code_mag input bound.)
    let k = 64usize;
    let rows = 6usize;
    let n = 3usize;
    for (bits, symmetric, act_pow) in
        [(2u32, true, 28u32), (3, true, 27), (4, true, 27), (8, true, 23), (8, false, 23)]
    {
        let (qmin, qmax) = qrange(bits, symmetric);
        let (qmin, qmax) = (qmin as i32, qmax as i32);
        // rows alternate the two grid edges; row 0 is all-qmax so its
        // products share a sign and the running sum grows monotonically —
        // the classic i32 wraparound shape
        let codes: Vec<i32> = (0..rows * k)
            .map(|i| if i / k == 0 || i % 3 == 0 { qmax } else { qmin })
            .collect();
        let scale: Vec<f32> = (0..rows).map(|r| 0.25 + 0.125 * r as f32).collect();
        let zp: Vec<f32> = (0..rows).map(|r| if r % 2 == 0 { 0.0 } else { 1.5 }).collect();
        let m =
            PackedMatrix::pack(&codes, rows, k, bits, qmin, scale.clone(), zp.clone()).unwrap();
        let act = (1i64 << act_pow) as f32;
        // batch row 0 all-positive (monotone growth), the rest alternating
        let xv: Vec<f32> = (0..n * k)
            .map(|i| if i / k == 0 || i % 2 == 0 { act } else { -act })
            .collect();
        let x = Tensor::from_f32(xv.clone(), &[n, k]).unwrap();
        // these magnitudes are far outside the 2²⁴ exactness window: the
        // auto route must refuse, only the explicit integer API runs
        assert!(
            !int_gemm_eligible(&x, &m),
            "{bits}-bit ±2^{act_pow} batch must be outside the exact window"
        );
        // independent i64 reference, same single-rounding epilogue
        let mut want = vec![0.0f32; n * rows];
        for i in 0..n {
            for j in 0..rows {
                let mut acc = 0i64;
                let mut sumx = 0i64;
                for t in 0..k {
                    let xt = xv[i * k + t] as i64;
                    acc += codes[j * k + t] as i64 * xt;
                    sumx += xt;
                }
                want[i * rows + j] = scale[j] * (acc as f32 - zp[j] * (sumx as f32));
            }
        }
        for isa in [Isa::Scalar, Isa::detect()] {
            let got = gemm_fused_int_with(&x, &m, &Dispatch::serial().with_isa(isa)).unwrap();
            assert_eq!(
                got.as_f32().unwrap(),
                want.as_slice(),
                "{bits}-bit (sym={symmetric}) ±2^{act_pow} widening path ({})",
                isa.label()
            );
        }
    }
    // activations past the explicit API's input bound are rejected, not
    // silently wrapped: 2³¹ exceeds i32::MAX / code_mag at any width
    let m = PackedMatrix::pack(&vec![1i32; 8], 1, 8, 8, 0, vec![1.0], vec![0.0]).unwrap();
    let huge = Tensor::from_f32(vec![(1i64 << 31) as f32; 8], &[1, 8]).unwrap();
    assert!(gemm_fused_int(&huge, &m, 1).is_err());
    assert!(!int_gemm_eligible(&huge, &m));
}

#[test]
fn in_register_unpack_is_bit_identical_to_the_scalar_walk() {
    // The in-register decode's acceptance pin: every bit width, widths that
    // straddle the packed-word boundary in both directions (cpw = ⌊32/bits⌋
    // is 16/10/8/4 — the 3-bit width is the nasty one: 10 codes + 2 wasted
    // bits per word), non-lane-multiple widths, and grid-edge codes pinned
    // at both ends by random_packed_zp.  All three destinations must equal
    // the scalar word walk bitwise on both arms.
    let mut rng = Pcg32::seeded(101);
    for bits in [2u32, 3, 4, 8] {
        let cpw = (32 / bits) as usize;
        for cols in [1, cpw - 1, cpw, cpw + 1, 2 * cpw, 2 * cpw + 3, 3 * cpw + 1, 33, 64] {
            let m = random_packed_zp(&mut rng, 3, cols, bits, false, false);
            let mut walk_i = vec![0i32; cols];
            let mut walk_f = vec![0.0f32; cols];
            let mut got_i = vec![0i32; cols];
            let mut got_f = vec![0.0f32; cols];
            let mut got_h = vec![0i16; cols];
            for r in 0..3 {
                m.unpack_row_i32(r, &mut walk_i);
                m.unpack_row(r, &mut walk_f);
                for isa in [Isa::Scalar, Isa::detect()] {
                    simd::unpack_codes_i32(isa, m.row_words(r), cols, bits, m.qmin(), &mut got_i);
                    assert_eq!(
                        got_i,
                        walk_i,
                        "i32 decode {bits}-bit cols={cols} row={r} ({})",
                        isa.label()
                    );
                    simd::unpack_codes_f32(isa, m.row_words(r), cols, bits, m.qmin(), &mut got_f);
                    assert_eq!(
                        got_f,
                        walk_f,
                        "f32 decode {bits}-bit cols={cols} row={r} ({})",
                        isa.label()
                    );
                    simd::unpack_codes_i16(isa, m.row_words(r), cols, bits, m.qmin(), &mut got_h);
                    let widened: Vec<i32> = got_h.iter().map(|&c| c as i32).collect();
                    assert_eq!(
                        widened,
                        walk_i,
                        "i16 decode {bits}-bit cols={cols} row={r} ({})",
                        isa.label()
                    );
                }
            }
        }
    }
    // k = 0: no words, no stores, no panic — on either arm
    let mut empty_i: Vec<i32> = Vec::new();
    let mut empty_f: Vec<f32> = Vec::new();
    let mut empty_h: Vec<i16> = Vec::new();
    for isa in [Isa::Scalar, Isa::detect()] {
        simd::unpack_codes_i32(isa, &[], 0, 4, -8, &mut empty_i);
        simd::unpack_codes_f32(isa, &[], 0, 3, -4, &mut empty_f);
        simd::unpack_codes_i16(isa, &[], 0, 2, -2, &mut empty_h);
    }
}

#[test]
fn i16_madd_route_is_bit_exact_against_the_rowwise_oracle() {
    // The madd acceptance pin: with in-window integral activations the
    // forced madd route, the forced i32 route, the auto route, and the f32
    // rowwise oracle must all agree bit-for-bit — per arm, serial and
    // parallel.  (IntRoute::Madd on the scalar arm runs the bit-identical
    // scalar emulation, so this pins the route even on non-AVX2 hardware
    // and under FLEXROUND_FORCE_NO_MADD.)
    Prop::new("madd route ≡ dot32 route ≡ rowwise, bitwise").cases(48).check(|rng| {
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let symmetric = rng.below(2) == 0;
        let zero_zp = rng.below(2) == 0;
        let rows = 1 + rng.below(20) as usize;
        let cols = 1 + rng.below(48) as usize;
        let n = 1 + rng.below(4) as usize;
        let m = random_packed_zp(rng, rows, cols, bits, symmetric, zero_zp);
        let amax = 20u32;
        let x = Tensor::from_f32(
            (0..n * cols).map(|_| rng.below(2 * amax + 1) as f32 - amax as f32).collect(),
            &[n, cols],
        )
        .map_err(|e| e.to_string())?;
        for isa in [Isa::Scalar, Isa::detect()] {
            let rowwise = gemm_fused_rowwise_isa(&x, &m, isa).map_err(|e| e.to_string())?;
            let want = rowwise.as_f32().map_err(|e| e.to_string())?;
            for workers in [1usize, 4] {
                let d = Dispatch::new(workers).with_isa(isa);
                for route in [IntRoute::Madd, IntRoute::Dot32, IntRoute::Auto] {
                    let got = gemm_fused_int_route(&x, &m, &d, route)
                        .map_err(|e| e.to_string())?;
                    if got.as_f32().map_err(|e| e.to_string())? != want {
                        return Err(format!(
                            "{route:?} ≠ rowwise ({bits}-bit {rows}×{cols} batch {n}, \
                             sym={symmetric}, zp0={zero_zp}, workers={workers}, {})",
                            isa.label()
                        ));
                    }
                }
            }
        }
        Ok(())
    });
    // batch-1 madd gemv decode fast path: a single activation row through
    // the forced madd route must reproduce its batched row bitwise
    let mut rng = Pcg32::seeded(57);
    for bits in [2u32, 3, 4, 8] {
        let m = random_packed_zp(&mut rng, 33, 29, bits, false, false);
        let batch = Tensor::from_f32(
            (0..4 * 29).map(|_| rng.below(41) as f32 - 20.0).collect(),
            &[4, 29],
        )
        .unwrap();
        for isa in [Isa::Scalar, Isa::detect()] {
            let d = Dispatch::serial().with_isa(isa);
            let full = gemm_fused_int_route(&batch, &m, &d, IntRoute::Madd).unwrap();
            for i in 0..4 {
                let row = batch.slice_rows(i, i + 1).unwrap();
                let one = gemm_fused_int_route(&row, &m, &d, IntRoute::Madd).unwrap();
                assert_eq!(
                    one.as_f32().unwrap(),
                    &full.as_f32().unwrap()[i * 33..(i + 1) * 33],
                    "{bits}-bit madd batch-1 row {i} ({})",
                    isa.label()
                );
            }
        }
    }
    // forcing madd on operands that cannot narrow to i16 is an error, not
    // a silent truncation — while Auto quietly falls back to the i32 path
    let m = random_packed_zp(&mut rng, 4, 8, 4, true, true);
    let x = Tensor::from_f32(vec![40_000.0; 8], &[1, 8]).unwrap();
    let d = Dispatch::serial();
    assert!(gemm_fused_int_route(&x, &m, &d, IntRoute::Madd).is_err());
    assert!(gemm_fused_int_route(&x, &m, &d, IntRoute::Auto).is_ok());
    assert!(gemm_fused_int_route(&x, &m, &d, IntRoute::Dot32).is_ok());
}

#[test]
fn i16_madd_pair_sum_overflow_bound_holds() {
    // The documented madd worst cases: both operands at i16::MAX leave
    // exactly one pair-sum of headroom (safe_k = 2, not 1), and the W8A16
    // extreme still allows 257 terms per i32 chunk.
    assert_eq!(int_safe_k(32_767, 32_767), 2);
    assert_eq!(int_safe_k(255, 32_767), 257);
    // int_safe_k-style bound prop: for any i16-bounded operand magnitudes
    // the _mm256_madd_epi16 pair-sum (2·cm·am) fits i32, safe_k keeps at
    // least one full pair per chunk, and no lane partial within a chunk
    // can leave i32 range
    Prop::new("madd pair-sum and lane partials fit i32").cases(64).check(|rng| {
        let cm = 1 + rng.below(32_767) as i64;
        let am = 1 + rng.below(32_767) as i64;
        if 2 * cm * am > i32::MAX as i64 {
            return Err(format!("pair-sum bound violated: 2·{cm}·{am} > i32::MAX"));
        }
        let sk = int_safe_k(cm, am) as i64;
        if sk < 2 {
            return Err(format!("safe_k {sk} < 2 for i16-bounded magnitudes {cm}·{am}"));
        }
        if sk * cm * am > i32::MAX as i64 {
            return Err(format!("lane partial can overflow: {sk}·{cm}·{am} > i32::MAX"));
        }
        Ok(())
    });
    // raw kernel at the absolute extremes: a single madd pair at maximum
    // magnitude must match the i64 reference on both arms
    for (a, b) in [
        (vec![i16::MAX; 2], vec![i16::MAX; 2]),
        (vec![i16::MIN + 1; 2], vec![i16::MAX; 2]),
        (vec![i16::MAX, i16::MIN + 1], vec![i16::MAX; 2]),
    ] {
        let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
        for isa in [Isa::Scalar, Isa::detect()] {
            assert_eq!(
                simd::dot_i16_madd(isa, &a, &b) as i64,
                want,
                "extreme madd pair ({})",
                isa.label()
            );
        }
    }
    // end-to-end through the forced madd route: symmetric W8 grid-edge
    // codes against ±32767 activations over K = 600 ≫ safe_k(127, 32767)
    // = 516, so the chunked i64-widening path engages — monotone same-sign
    // row 0 is the classic i32-wraparound shape; the result must equal an
    // independent i64 reference exactly, on both arms, madd and dot32
    let k = 600usize;
    let rows = 4usize;
    let n = 2usize;
    let (qmin, qmax) = qrange(8, true);
    let (qmin, qmax) = (qmin as i32, qmax as i32);
    assert!(int_safe_k(qmax.unsigned_abs() as i64, 32_767) < k);
    let codes: Vec<i32> = (0..rows * k)
        .map(|i| if i / k == 0 || i % 3 == 0 { qmax } else { qmin })
        .collect();
    let scale: Vec<f32> = (0..rows).map(|r| 0.25 + 0.125 * r as f32).collect();
    let zp: Vec<f32> = (0..rows).map(|r| if r % 2 == 0 { 0.0 } else { 1.5 }).collect();
    let m = PackedMatrix::pack(&codes, rows, k, 8, qmin, scale.clone(), zp.clone()).unwrap();
    let act = 32_767.0f32;
    let xv: Vec<f32> = (0..n * k)
        .map(|i| if i / k == 0 || i % 2 == 0 { act } else { -act })
        .collect();
    let x = Tensor::from_f32(xv.clone(), &[n, k]).unwrap();
    let mut want = vec![0.0f32; n * rows];
    for i in 0..n {
        for j in 0..rows {
            let mut acc = 0i64;
            let mut sumx = 0i64;
            for t in 0..k {
                let xt = xv[i * k + t] as i64;
                acc += codes[j * k + t] as i64 * xt;
                sumx += xt;
            }
            want[i * rows + j] = scale[j] * (acc as f32 - zp[j] * (sumx as f32));
        }
    }
    for isa in [Isa::Scalar, Isa::detect()] {
        let d = Dispatch::serial().with_isa(isa);
        for route in [IntRoute::Madd, IntRoute::Dot32] {
            let got = gemm_fused_int_route(&x, &m, &d, route).unwrap();
            assert_eq!(
                got.as_f32().unwrap(),
                want.as_slice(),
                "±32767 widening path, {route:?} ({})",
                isa.label()
            );
        }
    }
}
