//! KV-cached generation acceptance gate (DESIGN.md §Generation):
//!
//! * **parity** — `prefill(x[..t])` + `decode_step` logits match the
//!   full-context `Engine::forward_ctx` logits within 1e-5 at *every*
//!   position, for sequence lengths {1, 7, 64}, at 4 and 8 bits (the
//!   tentpole contract: the incremental path and the batch path are the
//!   same function);
//! * **determinism** — a fixed `--seed` replays the exact token stream, and
//!   the cached decoder emits the same stream as the full-context
//!   recompute baseline (greedy and temperature/top-k);
//! * **serving** — generation sessions through the micro-batch queue match
//!   the direct decode loop;
//! * **artifacts** — a pipeline-packed generation-complete artifact
//!   (blocks + tied lm head) round-trips through disk and decodes.

use flexround::block::{run_pipeline, synthetic_block_model, PipelineOpts, SyntheticBlockSpec};
use flexround::infer::generate::{self, GenOpts};
use flexround::infer::{Engine, PackedModel};
use flexround::runtime::Native;
use flexround::tensor::Tensor;
use flexround::util::rng::Pcg32;

fn lm_engine(bits: u32) -> Engine {
    let model = generate::synthetic_lm(2, 16, 4, 32, 8, 24, bits, 13).unwrap();
    Engine::new(model, 2)
}

fn hidden_rows(t: usize, d: usize, seed: u64) -> Tensor {
    let mut rng = Pcg32::seeded(seed);
    Tensor::from_f32((0..t * d).map(|_| rng.next_normal()).collect(), &[t, d]).unwrap()
}

#[test]
fn prefill_then_decode_matches_full_context_at_every_position() {
    for bits in [4u32, 8] {
        let engine = lm_engine(bits);
        let d = engine.model().in_width().unwrap();
        for t in [1usize, 7, 64] {
            let x = hidden_rows(t, d, 100 + t as u64);
            let full = engine.forward_ctx(&x, t).unwrap();
            let fv = full.as_f32().unwrap();
            let w = full.shape()[1];
            let tol = 1e-5 * (1.0 + full.abs_max());

            // (a) one-shot prefill emits the same logits at every position
            let (state, pre) = engine.prefill(&x).unwrap();
            assert_eq!(state.pos(), t);
            let dmax = pre.max_abs_diff(&full).unwrap();
            assert!(
                dmax <= tol,
                "prefill vs full-context at t={t}, {bits}-bit: max|Δ| {dmax} > {tol}"
            );

            // (b) prefill one row, then decode the rest token by token —
            // every step must match the full-context logits at its position
            let (mut st, first) = engine.prefill(&x.slice_rows(0, 1).unwrap()).unwrap();
            for (j, (a, b)) in first.as_f32().unwrap().iter().zip(&fv[..w]).enumerate() {
                assert!((a - b).abs() <= tol, "prefill[0] logit {j}: {a} vs {b}");
            }
            let xv = x.as_f32().unwrap();
            for i in 1..t {
                let logits = engine.decode_step(&mut st, &xv[i * d..(i + 1) * d]).unwrap();
                assert_eq!(st.pos(), i + 1);
                assert_eq!(logits.len(), w);
                for (j, (a, b)) in logits.iter().zip(&fv[i * w..(i + 1) * w]).enumerate() {
                    assert!(
                        (a - b).abs() <= tol,
                        "decode step {i} logit {j} drifts at t={t}, {bits}-bit: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn token_streams_are_deterministic_and_match_the_recompute_baseline() {
    let engine = lm_engine(4);
    let (_, prompt) = generate::random_prompt(engine.model(), 5, 21).unwrap();
    let opts = GenOpts { max_new: 12, temp: 0.8, top_k: 6, seed: 33 };
    let a = generate::generate(&engine, &prompt, &opts).unwrap();
    let b = generate::generate(&engine, &prompt, &opts).unwrap();
    assert_eq!(a.tokens, b.tokens, "a fixed seed must replay the exact stream");
    assert_eq!(a.tokens.len(), 12);
    let v = generate::vocab(engine.model()).unwrap();
    assert!(a.tokens.iter().all(|&t| t < v));

    let c = generate::generate_recompute(&engine, &prompt, &opts).unwrap();
    assert_eq!(a.tokens, c.tokens, "cached and recompute decoders must agree (sampled)");

    let greedy = GenOpts { temp: 0.0, ..opts };
    let g1 = generate::generate(&engine, &prompt, &greedy).unwrap();
    let g2 = generate::generate_recompute(&engine, &prompt, &greedy).unwrap();
    assert_eq!(g1.tokens, g2.tokens, "cached and recompute decoders must agree (greedy)");

    // a different seed takes the sampled stream elsewhere eventually
    let other = GenOpts { seed: 34, ..opts };
    let d = generate::generate(&engine, &prompt, &other).unwrap();
    assert_eq!(d.tokens.len(), 12);
}

#[test]
fn pipeline_packed_artifact_is_generation_complete() {
    // pipeline → packed_lm_model → disk → reload → generate: the paper's
    // deployment story end to end, with no FP weights in the artifact
    let fx = synthetic_block_model(&SyntheticBlockSpec::default()).unwrap();
    let backend = Native::new();
    let sess = fx.session(&backend);
    let outcome = run_pipeline(&sess, &PipelineOpts::new("rtn", 4)).unwrap();
    let pm = sess.packed_lm_model(&outcome.result).unwrap();
    assert!(pm.has_blocks());
    let last = pm.units.last().unwrap();
    assert_eq!((last.kind.as_str(), last.name.as_str()), ("stack", "head"));
    assert_eq!(generate::vocab(&pm).unwrap(), 24);

    let path = std::env::temp_dir()
        .join(format!("flexround_genpack_{}.fxt", std::process::id()));
    pm.save(&path).unwrap();
    let reloaded = PackedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(reloaded, pm, "generation artifact must round-trip bit-exactly");

    let engine = Engine::new(reloaded, 2);
    let (_, prompt) = generate::random_prompt(engine.model(), 4, 3).unwrap();
    let opts = GenOpts { max_new: 8, temp: 0.0, top_k: 0, seed: 1 };
    let gen = generate::generate(&engine, &prompt, &opts).unwrap();
    assert_eq!(gen.tokens.len(), 8);
    let again = generate::generate(&engine, &prompt, &opts).unwrap();
    assert_eq!(gen.tokens, again.tokens);
    // and the decode loop agrees with the full-context recompute over the
    // packed artifact too
    let base = generate::generate_recompute(&engine, &prompt, &opts).unwrap();
    assert_eq!(gen.tokens, base.tokens);
}

#[test]
fn decode_cost_does_not_grow_with_the_cache() {
    // A cheap O(1)-shape sanity check (the real curve lives in
    // benches/generate.rs): the KV cache after many decode steps holds
    // exactly prompt + generated rows, and decode keeps answering at the
    // full vocabulary width.
    let engine = lm_engine(4);
    let (_, prompt) = generate::random_prompt(engine.model(), 2, 40).unwrap();
    let (mut st, logits) = engine.prefill(&prompt).unwrap();
    let w = logits.shape()[1];
    let mut rng = Pcg32::seeded(50);
    let mut last = logits.as_f32().unwrap()[w..2 * w].to_vec();
    for step in 0..30 {
        let tok = generate::sample_token(&last, 1.0, 8, &mut rng);
        let row = generate::embed_token(engine.model(), tok).unwrap();
        last = engine.decode_step(&mut st, &row).unwrap();
        assert_eq!(last.len(), w);
        assert_eq!(st.pos(), 3 + step);
    }
    // 2 blocks × (K + V) × pos × d × 4 bytes
    assert_eq!(st.kv().bytes(), 2 * 2 * 32 * 16 * 4);
}
