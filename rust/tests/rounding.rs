//! Rounding-scheme integration tests (DESIGN.md §Rounding-Schemes):
//!
//! * trait conformance — every [`Rounding`] impl must emit codes on the
//!   integer grid at 2/3/4/8 bits, export `Ŵ` derived from those same
//!   codes, and collapse its training-time forward onto the hard export at
//!   convergence;
//! * AdaRound backward vs finite differences — the same frozen-offset
//!   surrogate discipline the FlexRound STE check uses, extended with the
//!   annealed rounding regularizer;
//! * AdaRound end-to-end — reconstruction through the shared Adam loop must
//!   not leave the hard export worse than RTN, and a [`Session::quantize`]
//!   run resolves its init pack through the flexround-grid fallback;
//! * the W4A8 deployment round trip — `packed_model_with_acts` → `.fxt` on
//!   disk → reload → `Engine::forward` runs the integer-domain fused kernel
//!   within 1e-4 of the f32 fake-quant reference.

use flexround::coordinator::{Plan, Session};
use flexround::infer::{Engine, PackedModel};
use flexround::manifest::{LayerInfo, Manifest, ModelInfo, PackEntry, UnitInfo};
use flexround::recon::rounding::adaround::REG_WEIGHT;
use flexround::recon::rounding::{beta_schedule, scale_codes, scheme_for, Rounding, SlotParams};
use flexround::recon::{self, LayerDef, LayerSlots, ReconSettings};
use flexround::runtime::Native;
use flexround::tensor::{minmax_scale, qrange, Tensor};
use flexround::util::prop::Prop;
use flexround::util::rng::Pcg32;
use std::collections::BTreeMap;

// ---------------------------------------------------------------------------
// Trait conformance: codes on grid, export ≡ scale_codes, forward → export
// ---------------------------------------------------------------------------

/// The contract pinned for every scheme: codes integral and inside
/// `[qmin, qmax]`; `export` returns exactly (`scale_codes(codes)`, `codes`);
/// and — when `converged` — the training-time forward equals the export.
fn check_conformance(
    scheme: &dyn Rounding,
    w: &Tensor,
    p: &SlotParams,
    qmin: f32,
    qmax: f32,
    converged: bool,
) {
    let name = scheme.name();
    let codes = scheme.codes(w, p, qmin, qmax).unwrap();
    for &c in &codes.to_f32_vec() {
        assert!(
            (qmin..=qmax).contains(&c) && (c - c.round()).abs() < 1e-6,
            "{name}: code {c} off the [{qmin}, {qmax}] grid"
        );
    }
    let (what, codes2) = scheme.export(w, p, qmin, qmax).unwrap();
    assert_eq!(
        codes.to_f32_vec(),
        codes2.to_f32_vec(),
        "{name}: export codes desync from Rounding::codes"
    );
    let derived = scale_codes(&codes, p.s1, p.zp).unwrap();
    let d = what.max_abs_diff(&derived).unwrap();
    assert!(d <= 1e-6, "{name}: export Ŵ drifts {d} from s1·(codes − zp)");
    if converged {
        let fwd = scheme.forward(w, p, qmin, qmax).unwrap();
        let d = fwd.max_abs_diff(&what).unwrap();
        assert!(
            d <= 1e-5,
            "{name}: converged forward drifts {d} from the hard export"
        );
    }
}

#[test]
fn flexround_conformance_across_bit_widths() {
    let scheme = scheme_for("flexround").unwrap();
    let mut rng = Pcg32::seeded(31);
    for bits in [2u32, 3, 4, 8] {
        let (r, c) = (6usize, 10usize);
        let wv: Vec<f32> = (0..r * c).map(|_| rng.next_normal() * 0.5).collect();
        let w = Tensor::from_f32(wv.clone(), &[r, c]).unwrap();
        let s1: Vec<f32> = (0..r)
            .map(|i| minmax_scale(&wv[i * c..(i + 1) * c], bits, true).0)
            .collect();
        let s1 = Tensor::from_f32(s1, &[r, 1]).unwrap();
        let s2 = Tensor::from_f32(
            (0..r * c).map(|_| 0.85 + 0.3 * rng.next_f32()).collect(),
            &[r, c],
        )
        .unwrap();
        let s3 = Tensor::from_f32(
            (0..r).map(|_| 0.9 + 0.2 * rng.next_f32()).collect(),
            &[r, 1],
        )
        .unwrap();
        let s4 = Tensor::from_f32(
            (0..c).map(|_| 0.9 + 0.2 * rng.next_f32()).collect(),
            &[1, c],
        )
        .unwrap();
        let zp = Tensor::zeros(&[r, 1]);
        let (qmin, qmax) = qrange(bits, true);
        let p = SlotParams {
            s1: &s1,
            zp: &zp,
            s2: Some(&s2),
            s3: Some(&s3),
            s4: Some(&s4),
            v: None,
        };
        // FlexRound's forward is hard-rounded at every step, so the
        // forward ≡ export leg of the contract holds unconditionally
        check_conformance(scheme, &w, &p, qmin, qmax, true);
    }
}

#[test]
fn adaround_conformance_across_bit_widths() {
    let scheme = scheme_for("adaround").unwrap();
    let mut rng = Pcg32::seeded(67);
    for bits in [2u32, 3, 4, 8] {
        let (r, c) = (6usize, 10usize);
        let wv: Vec<f32> = (0..r * c).map(|_| rng.next_normal() * 0.5).collect();
        let w = Tensor::from_f32(wv.clone(), &[r, c]).unwrap();
        let s1: Vec<f32> = (0..r)
            .map(|i| minmax_scale(&wv[i * c..(i + 1) * c], bits, true).0)
            .collect();
        let s1 = Tensor::from_f32(s1, &[r, 1]).unwrap();
        let zp = Tensor::zeros(&[r, 1]);
        // saturated V: every h(V) pinned at 0 or 1 — the converged state the
        // regularizer drives training toward
        let v = Tensor::from_f32(
            (0..r * c).map(|_| if rng.below(2) == 0 { -20.0 } else { 20.0 }).collect(),
            &[r, c],
        )
        .unwrap();
        let (qmin, qmax) = qrange(bits, true);
        let p = SlotParams { s1: &s1, zp: &zp, s2: None, s3: None, s4: None, v: Some(&v) };
        check_conformance(scheme, &w, &p, qmin, qmax, true);

        // mid-training V (h in the open interval): codes/export must still
        // honor the grid contract even though the forward is soft
        let v_soft = Tensor::from_f32(
            (0..r * c).map(|_| (rng.next_f32() - 0.5) * 4.0).collect(),
            &[r, c],
        )
        .unwrap();
        let p = SlotParams { s1: &s1, zp: &zp, s2: None, s3: None, s4: None, v: Some(&v_soft) };
        check_conformance(scheme, &w, &p, qmin, qmax, false);
    }
}

#[test]
fn adaround_conformance_on_asymmetric_grid() {
    // nonzero zero-point: the export scaling and code clamp must both carry
    // it (8-bit asymmetric is the activation-grid convention)
    let scheme = scheme_for("adaround").unwrap();
    let mut rng = Pcg32::seeded(5);
    let (r, c) = (4usize, 7usize);
    let w = Tensor::from_f32(
        (0..r * c).map(|_| rng.next_normal() * 0.3 + 0.4).collect(),
        &[r, c],
    )
    .unwrap();
    let s1 = Tensor::from_f32(vec![0.01, 0.02, 0.015, 0.03], &[r, 1]).unwrap();
    let zp = Tensor::from_f32(vec![100.0, 90.0, 120.0, 80.0], &[r, 1]).unwrap();
    let v = Tensor::from_f32(
        (0..r * c).map(|_| if rng.below(2) == 0 { -20.0 } else { 20.0 }).collect(),
        &[r, c],
    )
    .unwrap();
    let (qmin, qmax) = qrange(8, false);
    let p = SlotParams { s1: &s1, zp: &zp, s2: None, s3: None, s4: None, v: Some(&v) };
    check_conformance(scheme, &w, &p, qmin, qmax, true);
}

// ---------------------------------------------------------------------------
// AdaRound backward vs finite differences
// ---------------------------------------------------------------------------

/// f64 surrogate of the AdaRound objective contribution:
/// `Σ g·Ŵ(V) + λ·Σ (1 − |2h(V) − 1|^β)`.  Smooth in `V` everywhere off the
/// rectifier and clip boundaries (the floor term is frozen — it does not
/// depend on `V`), so central differences of this must match
/// `AdaRound::backward`'s `dv`, which folds the regularizer in.
#[allow(clippy::too_many_arguments)]
fn ada_surrogate(
    w: &[f64],
    r: usize,
    c: usize,
    s1: &[f64],
    zp: &[f64],
    v: &[f64],
    g: &[f64],
    qmin: f64,
    qmax: f64,
    beta: f64,
) -> f64 {
    let mut acc = 0.0;
    for i in 0..r {
        for j in 0..c {
            let k = i * c + j;
            let sig = 1.0 / (1.0 + (-v[k]).exp());
            let h = (1.2 * sig - 0.1).clamp(0.0, 1.0);
            let n = (w[k] / s1[i]).floor() + h + zp[i];
            let n_c = n.clamp(qmin, qmax);
            acc += g[k] * s1[i] * (n_c - zp[i]);
            let t = 2.0 * h - 1.0;
            acc += (REG_WEIGHT as f64) * (1.0 - t.abs().powf(beta));
        }
    }
    acc
}

#[test]
fn adaround_backward_matches_finite_differences() {
    Prop::new("adaround dv vs finite differences").cases(25).check(|rng| {
        let (r, c) = (2 + rng.below(3) as usize, 2 + rng.below(4) as usize);
        let wv: Vec<f32> = (0..r * c).map(|_| rng.next_normal() * 0.5).collect();
        let s1v: Vec<f32> = (0..r).map(|_| 0.05 + 0.2 * rng.next_f32()).collect();
        let zpv: Vec<f32> = vec![0.0; r];
        let vv: Vec<f32> = (0..r * c).map(|_| (rng.next_f32() - 0.5) * 6.0).collect();
        let gv: Vec<f32> = (0..r * c).map(|_| rng.next_normal()).collect();
        let (qmin, qmax) = (-16.0f32, 15.0f32);
        // β from the live schedule — mid-training values exercise the
        // regularizer's |2h−1|^{β−1} factor at realistic exponents
        let beta = beta_schedule(40 + rng.below(50) as usize, 100);

        let f64v = |v: &[f32]| v.iter().map(|&x| x as f64).collect::<Vec<f64>>();
        let (wd, s1d, zpd, vd, gd) = (f64v(&wv), f64v(&s1v), f64v(&zpv), f64v(&vv), f64v(&gv));

        // skip draws where any element sits on a kink of the surrogate: the
        // rectifier boundary (h hits 0/1), the clip boundary, or the
        // regularizer's |2h−1| = 0 crease
        for i in 0..r {
            for j in 0..c {
                let k = i * c + j;
                let sig = 1.0 / (1.0 + (-vd[k]).exp());
                let hraw = 1.2 * sig - 0.1;
                if hraw < 3e-2 || hraw > 1.0 - 3e-2 {
                    return Ok(());
                }
                if (2.0 * hraw - 1.0).abs() < 5e-2 {
                    return Ok(());
                }
                let n = (wd[k] / s1d[i]).floor() + hraw + zpd[i];
                if (n - qmin as f64).abs() < 2e-2 || (n - qmax as f64).abs() < 2e-2 {
                    return Ok(());
                }
            }
        }

        let w = Tensor::from_f32(wv, &[r, c]).unwrap();
        let s1 = Tensor::from_f32(s1v, &[r, 1]).unwrap();
        let zp = Tensor::from_f32(zpv, &[r, 1]).unwrap();
        let v = Tensor::from_f32(vv, &[r, c]).unwrap();
        let g = Tensor::from_f32(gv, &[r, c]).unwrap();
        let p = SlotParams { s1: &s1, zp: &zp, s2: None, s3: None, s4: None, v: Some(&v) };
        let scheme = scheme_for("adaround").map_err(|e| e.to_string())?;
        let fg = scheme
            .backward(&w, &p, &g, qmin, qmax, beta)
            .map_err(|e| e.to_string())?;
        let dv = fg.dv.as_ref().expect("adaround fills dv");
        let dvv = dv.as_f32().unwrap();
        // frozen slots stay frozen
        assert!(fg.ds1.as_f32().unwrap().iter().all(|&x| x == 0.0));
        assert!(fg.ds2.is_none() && fg.ds3.is_none() && fg.ds4.is_none());

        for k in 0..r * c {
            let mut hi = vd.clone();
            let mut lo = vd.clone();
            let eps = 1e-5;
            hi[k] += eps;
            lo[k] -= eps;
            let num = (ada_surrogate(&wd, r, c, &s1d, &zpd, &hi, &gd, qmin as f64, qmax as f64, beta)
                - ada_surrogate(&wd, r, c, &s1d, &zpd, &lo, &gd, qmin as f64, qmax as f64, beta))
                / (2.0 * eps);
            let tol = 2e-3 * num.abs().max(dvv[k].abs() as f64).max(1.0);
            if ((dvv[k] as f64) - num).abs() > tol {
                return Err(format!("dv[{k}]: analytic {} vs numeric {num} (β {beta})", dvv[k]));
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// AdaRound end-to-end through the shared Adam loop
// ---------------------------------------------------------------------------

#[test]
fn adaround_reconstruction_export_not_worse_than_rtn() {
    let p = recon::synthetic_problem_adaround(12, 24, 192, 3, 7);
    let slots: Vec<LayerSlots> = recon::synthetic_slots_adaround();
    let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
    let scheme = scheme_for("adaround").unwrap();
    let cfg = ReconSettings {
        iters: 400,
        lr: 1e-2,
        batch: 32,
        qmin: p.qmin,
        qmax: p.qmax,
        workers: 2,
        verbose: false,
        tag: "test/adaround".to_string(),
        scheme,
    };
    let mut rng = Pcg32::seeded(7);
    let r = recon::reconstruct_unit(
        &layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng,
    )
    .unwrap();
    assert!(r.final_loss.is_finite() && r.first_loss.is_finite());
    assert!(
        r.final_loss <= r.first_loss,
        "soft loss must not regress: {} → {}",
        r.first_loss,
        r.final_loss
    );

    // hard-export MSE vs the RTN baseline on the same grid (init_v starts
    // AdaRound exactly at RTN, so learning may only hold or improve it —
    // 2% slack absorbs the rounding regularizer's pull)
    let sp = slots[0].resolve(&r.params);
    let (what, _) = scheme.export(&p.w, &sp, p.qmin, p.qmax).unwrap();
    let mse_ada = p.x.matmul_nt(&what).unwrap().mse(&p.y).unwrap() as f64;
    let what_rtn =
        recon::fq_forward(&p.w, &p.params[0], None, None, None, &p.params[2], p.qmin, p.qmax)
            .unwrap();
    let mse_rtn = p.x.matmul_nt(&what_rtn).unwrap().mse(&p.y).unwrap() as f64;
    assert!(
        mse_ada <= mse_rtn * 1.02,
        "adaround export MSE {mse_ada} worse than RTN {mse_rtn}"
    );
}

// ---------------------------------------------------------------------------
// Session-level fixture (adaround fallback init + the W4A8 round trip)
// ---------------------------------------------------------------------------

const BITS: u32 = 4;

fn entry(name: &str, shape: &[usize], learnable: bool) -> PackEntry {
    PackEntry { name: name.to_string(), shape: shape.to_vec(), learnable }
}

fn linear_unit(name: &str, layer: &str, rows: usize, cols: usize) -> UnitInfo {
    let mut packs = BTreeMap::new();
    packs.insert(
        "flexround.w".to_string(),
        vec![
            entry(&format!("{layer}.s1"), &[rows, 1], true),
            entry(&format!("{layer}.s2"), &[rows, cols], true),
            entry(&format!("{layer}.s3"), &[rows, 1], true),
            entry(&format!("{layer}.s4"), &[1, cols], true),
            entry(&format!("{layer}.zp"), &[rows, 1], false),
        ],
    );
    packs.insert(
        "adaround.w".to_string(),
        vec![
            entry(&format!("{layer}.s1"), &[rows, 1], false),
            entry(&format!("{layer}.v"), &[rows, cols], true),
            entry(&format!("{layer}.zp"), &[rows, 1], false),
        ],
    );
    UnitInfo {
        name: name.to_string(),
        kind: "linear".to_string(),
        bits_override: None,
        in_shape: vec![cols],
        out_shape: vec![rows],
        act_sites: 0,
        heads: 1,
        layers: vec![LayerInfo {
            name: layer.to_string(),
            kind: "linear".to_string(),
            rows,
            cols,
            conv_shape: None,
            stride: 1,
        }],
        artifacts: BTreeMap::new(),
        packs,
    }
}

struct Fixture {
    man: Manifest,
    weights: BTreeMap<String, Tensor>,
    inits: BTreeMap<String, Tensor>,
    data: BTreeMap<String, Tensor>,
}

/// Two chained linear units (12 → 8 → 6), biases included, built in memory.
/// Only FlexRound init packs are exported — the adaround runs below resolve
/// through `Session`'s flexround-grid fallback, like real pre-zoo exports.
fn synthetic_fixture() -> Fixture {
    let mut rng = Pcg32::seeded(4321);
    let dims = [(8usize, 12usize), (6usize, 8usize)];
    let mut weights = BTreeMap::new();
    let mut inits = BTreeMap::new();
    let mut units = Vec::new();
    for (ui, &(rows, cols)) in dims.iter().enumerate() {
        let uname = format!("u{ui}");
        let wv: Vec<f32> = (0..rows * cols).map(|_| rng.next_normal() * 0.5).collect();
        let w = Tensor::from_f32(wv.clone(), &[rows, cols]).unwrap();
        weights.insert(format!("w/{uname}/fc"), w);
        let bias: Vec<f32> = (0..rows).map(|_| rng.next_normal() * 0.1).collect();
        weights.insert(format!("b/{uname}/fc"), Tensor::from_f32(bias, &[rows]).unwrap());
        let s1: Vec<f32> = (0..rows)
            .map(|r| minmax_scale(&wv[r * cols..(r + 1) * cols], BITS, true).0)
            .collect();
        let pfx = format!("init/{uname}/flexround/b{BITS}");
        inits.insert(format!("{pfx}/fc.s1"), Tensor::from_f32(s1, &[rows, 1]).unwrap());
        inits.insert(format!("{pfx}/fc.zp"), Tensor::zeros(&[rows, 1]));
        inits.insert(format!("{pfx}/fc.s2"), Tensor::full(&[rows, cols], 1.0));
        inits.insert(format!("{pfx}/fc.s3"), Tensor::full(&[rows, 1], 1.0));
        inits.insert(format!("{pfx}/fc.s4"), Tensor::full(&[1, cols], 1.0));
        units.push(linear_unit(&uname, "fc", rows, cols));
    }

    let calib_n = 64;
    let calib = Tensor::from_f32(
        (0..calib_n * dims[0].1).map(|_| rng.next_normal()).collect(),
        &[calib_n, dims[0].1],
    )
    .unwrap();
    let mut data = BTreeMap::new();
    let mut datasets = BTreeMap::new();
    datasets.insert("calib_x".to_string(), vec![calib_n, dims[0].1]);
    data.insert("calib_x".to_string(), calib);

    let mut lr_default = BTreeMap::new();
    lr_default.insert("flexround".to_string(), 4e-3);
    lr_default.insert("adaround".to_string(), 1e-2);
    let model = ModelInfo {
        name: "m".to_string(),
        kind: "cnn".to_string(),
        task: "synthetic".to_string(),
        fp_metric: BTreeMap::new(),
        symmetric: true,
        per_channel: true,
        bits_w: vec![BITS],
        abits: vec![8],
        methods_w: vec!["flexround".to_string(), "adaround".to_string()],
        methods_wa: vec![],
        calib_n,
        calib_batch: 16,
        seq: None,
        units,
        embed_artifact: None,
        head_artifacts: BTreeMap::new(),
        weights_file: "unused.fxt".to_string(),
        init_file: "unused.fxt".to_string(),
        data_file: "unused.fxt".to_string(),
        datasets,
        iters_default: 0,
        lr_default,
        drop_p_default: 0.0,
    };
    let mut models = BTreeMap::new();
    models.insert("m".to_string(), model);
    let man = Manifest { dir: std::env::temp_dir(), calib_batch: 16, models };
    Fixture { man, weights, inits, data }
}

fn open<'a>(fx: &'a Fixture, backend: &'a Native) -> Session<'a> {
    Session {
        backend,
        man: &fx.man,
        model: fx.man.model("m").unwrap(),
        weights: fx.weights.clone(),
        inits: fx.inits.clone(),
        data: fx.data.clone(),
    }
}

#[test]
fn adaround_session_quantize_with_fallback_init_packs() {
    let fx = synthetic_fixture();
    let backend = Native::with_workers(2);
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "adaround");
    plan.iters = 40;
    let result = sess.quantize(&plan).unwrap();
    for u in &result.units {
        assert!(u.final_loss.is_finite(), "unit {} loss NaN", u.unit);
        assert!(
            u.final_loss <= u.first_loss * 1.05,
            "unit {}: adaround loss regressed {} → {}",
            u.unit,
            u.first_loss,
            u.final_loss
        );
    }
    // the learned decisions export and pack like any other scheme, and the
    // packed engine agrees with the generic quantized chain
    let pm = sess.packed_model(&result).unwrap();
    let engine = Engine::new(pm, 2);
    let calib = sess.dataset("calib_x").unwrap();
    let chunks = sess.first_unit_inputs(calib).unwrap();
    let mut want = chunks.clone();
    for (unit, st) in sess.model.units.iter().zip(&result.units) {
        want = sess.advance_q(unit, st, "w", &want).unwrap();
    }
    for (chunk, want) in chunks.iter().zip(&want) {
        let got = engine.forward(chunk).unwrap();
        let d = got.max_abs_diff(want).unwrap();
        let tol = 1e-4 * (1.0 + want.abs_max());
        assert!(d <= tol, "adaround packed engine drift {d} > {tol}");
    }
}

#[test]
fn w4a8_pack_roundtrip_serves_integer_domain_with_parity() {
    let fx = synthetic_fixture();
    let backend = Native::with_workers(2);
    let sess = open(&fx, &backend);
    let mut plan = Plan::new("m", "flexround");
    plan.iters = 30;
    let result = sess.quantize(&plan).unwrap();

    let pm = sess.packed_model_with_acts(&result, 8).unwrap();
    for u in &pm.units {
        for l in &u.layers {
            let aq = l.act.expect("every stack layer must carry a calibrated act grid");
            assert_eq!(aq.abits, 8);
            assert!(aq.step > 0.0 && aq.zp >= 0.0);
        }
    }

    // the actq records survive the artifact round trip
    let path = std::env::temp_dir()
        .join(format!("flexround_w4a8_roundtrip_{}.fxt", std::process::id()));
    pm.save(&path).unwrap();
    let loaded = PackedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(pm, loaded);

    // fused forward (integer-domain act kernel) vs the f32 fake-quant
    // reference path — the W4A8 parity acceptance gate
    let engine = Engine::new(loaded, 2);
    let chunks = sess.first_unit_inputs(sess.dataset("calib_x").unwrap()).unwrap();
    let before = flexround::obs::value("flexround_fused_gemm_act_int_total").unwrap_or(0.0);
    for chunk in &chunks {
        let got = engine.forward(chunk).unwrap();
        let want = engine.forward_unfused(chunk).unwrap();
        let d = got.max_abs_diff(&want).unwrap();
        let tol = 1e-4 * (1.0 + want.abs_max());
        assert!(d <= tol, "W4A8 integer-domain vs fake-quant reference: {d} > {tol}");
    }
    if flexround::obs::enabled() {
        let after = flexround::obs::value("flexround_fused_gemm_act_int_total").unwrap_or(0.0);
        // 2 units × 1 act layer per chunk, at minimum
        assert!(
            after >= before + 2.0 * chunks.len() as f64,
            "act-int kernel counter did not advance: {before} → {after}"
        );
    }

    // and the quantized activations genuinely bite: a W4A8 forward must
    // differ from the weight-only engine (else the grid is a no-op)
    let engine_w = sess.packed_engine(&result).unwrap();
    let a = engine.forward(&chunks[0]).unwrap();
    let b = engine_w.forward(&chunks[0]).unwrap();
    assert!(
        a.max_abs_diff(&b).unwrap() > 0.0,
        "activation quantization had no effect on the forward"
    );
}
