//! `cargo bench --bench obs` — overhead microbench for the observability
//! layer, and the kill-switch acceptance gate (DESIGN.md §Observability):
//!
//! * the cost of a span with `FLEXROUND_OBS=off` — must stay in the
//!   nanosecond range (the gate **fails the run** above [`OFF_NS_MAX`]
//!   ns/op: a disabled span is one relaxed atomic load and must never read
//!   the clock);
//! * the enabled span (two clock reads + one seqlock ring write);
//! * the per-op cost of a cached counter inc and a histogram record — the
//!   primitives the scheduler and serve loops pay per step/batch.
//!
//! Emits machine-readable results to `BENCH_obs.json` at the repo root.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_MS  per-measurement budget in ms (default 300)

use flexround::obs;
use flexround::ser::json::{self, Json};
use flexround::util::stats::{bench, BenchResult};
use std::time::Duration;

/// Span calls per timed iteration (amortizes the harness clock reads).
const INNER: usize = 1000;

/// Acceptance ceiling for the disabled span, ns/op.  The real cost is a
/// relaxed load plus an `Option` construction — single-digit ns — so 100
/// leaves a wide margin for noisy CI machines while still catching any
/// accidental clock read or allocation on the off path.
const OFF_NS_MAX: f64 = 100.0;

fn per_op_ns(r: &BenchResult) -> f64 {
    r.min / INNER as f64 * 1e9
}

fn ns_json(r: &BenchResult) -> Json {
    Json::object(vec![
        ("iters", Json::from_f64(r.iters as f64)),
        ("ns_per_op_min", Json::from_f64(per_op_ns(r))),
        ("ns_per_op_p50", Json::from_f64(r.p50 / INNER as f64 * 1e9)),
    ])
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(300),
    );

    // ---- disabled path: the kill-switch gate ----
    println!("== span overhead, FLEXROUND_OBS=off ({INNER} spans/iter) ==");
    obs::set_enabled(false);
    let off = bench("span_disabled", budget, 20_000, || {
        for _ in 0..INNER {
            std::hint::black_box(obs::span("bench/span"));
        }
    });
    println!("{}", off.report());
    let off_ns = per_op_ns(&off);
    println!("  → disabled span costs {off_ns:.1} ns/op (gate: < {OFF_NS_MAX} ns)");

    // ---- enabled paths ----
    println!("== enabled primitives ({INNER} ops/iter) ==");
    obs::set_enabled(true);
    let on = bench("span_enabled", budget, 20_000, || {
        for _ in 0..INNER {
            std::hint::black_box(obs::span("bench/span"));
        }
    });
    println!("{}", on.report());
    println!("  → enabled span costs {:.1} ns/op", per_op_ns(&on));

    let c = obs::counter("flexround_bench_obs_counter_total");
    let ctr = bench("counter_inc_cached", budget, 20_000, || {
        for _ in 0..INNER {
            c.inc();
        }
    });
    println!("{}", ctr.report());

    let h = obs::histogram("flexround_bench_obs_hist");
    let hist = bench("hist_record", budget, 20_000, || {
        for i in 0..INNER {
            h.record(0.001 + i as f64 * 1e-5);
        }
    });
    println!("{}", hist.report());

    // ---- BENCH_obs.json at the repo root ----
    let doc = Json::object(vec![
        ("bench", Json::from_str_val("obs")),
        ("inner_ops_per_iter", Json::from_f64(INNER as f64)),
        ("span_disabled", ns_json(&off)),
        ("span_enabled", ns_json(&on)),
        ("counter_inc", ns_json(&ctr)),
        ("hist_record", ns_json(&hist)),
        ("off_gate_ns", Json::from_f64(OFF_NS_MAX)),
        ("off_gate_pass", Json::Bool(off_ns < OFF_NS_MAX)),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_obs.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    if off_ns >= OFF_NS_MAX {
        eprintln!(
            "FAIL: disabled span costs {off_ns:.1} ns/op (≥ {OFF_NS_MAX}); the kill switch \
             must keep the off path free of clock reads"
        );
        std::process::exit(1);
    }
}
