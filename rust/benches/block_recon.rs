//! `cargo bench --bench block_recon` — perf harness for the block-by-block
//! reconstruction pipeline (DESIGN.md §Block-Reconstruction):
//!
//! * FP-input vs quantized-input propagation: the quant mode maintains a
//!   second activation chain and re-forwards it through every learned
//!   block, so this is the cost of the paper's sequential protocol;
//! * cached (disk-spilled) vs in-memory activations at a deliberately tiny
//!   byte budget — the streaming overhead of a calibration set that does
//!   not fit in RAM.
//!
//! Emits machine-readable results to `BENCH_block_recon.json` at the repo
//! root, alongside the human-readable stdout lines.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_ITERS   Adam steps per block (default 30)

use flexround::block::{
    run_pipeline, synthetic_block_model, PipelineOpts, ReconInput, SyntheticBlockSpec,
};
use flexround::runtime::Native;
use flexround::ser::json::{self, Json};
use std::time::Instant;

fn main() {
    let iters: usize = std::env::var("FLEXROUND_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    let spec = SyntheticBlockSpec {
        blocks: 2,
        d: 32,
        heads: 4,
        mlp: 64,
        seq: 8,
        calib_seqs: 16,
        eval_seqs: 4,
        chunk_seqs: 4,
        vocab: 48,
        bits: 4,
        seed: 7,
    };
    let fx = synthetic_block_model(&spec).expect("synthetic block model");
    let backend = Native::new();
    let sess = fx.session(&backend);

    let mut opts = PipelineOpts::new("flexround", spec.bits);
    opts.iters = iters;
    opts.lr = 3e-3;

    println!(
        "== block pipeline ({} blocks, d={}, heads={}, mlp={}, seq={}, {} calib seqs, {iters} iters/block) ==",
        spec.blocks, spec.d, spec.heads, spec.mlp, spec.seq, spec.calib_seqs
    );
    let mut rows: Vec<(&str, f64, usize)> = Vec::new();
    let run = |opts: &PipelineOpts| -> (f64, usize) {
        let t0 = Instant::now();
        let out = run_pipeline(&sess, opts).expect("pipeline run");
        (t0.elapsed().as_secs_f64(), out.spilled_chunks)
    };

    // FP-input vs quantized-input propagation, all in memory
    for mode in [ReconInput::Fp, ReconInput::Quant] {
        opts.recon_input = mode;
        opts.cache_dir = None;
        opts.cache_budget_bytes = 0;
        let (secs, _) = run(&opts);
        let name: &str = match mode {
            ReconInput::Fp => "fp_input_in_memory",
            ReconInput::Quant => "quant_input_in_memory",
        };
        println!("{name:<26} {:.3}s", secs);
        rows.push((name, secs, 0));
    }

    // cached vs in-memory at a tiny budget (quant mode, the expensive one)
    let dir = std::env::temp_dir().join(format!("flexround_bench_blockcache_{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("bench cache dir");
    opts.recon_input = ReconInput::Quant;
    opts.cache_dir = Some(dir.clone());
    // one chunk = chunk_seqs·seq·d·4 bytes; keep ~1.5 chunks resident
    opts.cache_budget_bytes = spec.chunk_seqs * spec.seq * spec.d * 6;
    let (secs_cached, spilled) = run(&opts);
    println!("quant_input_disk_cached    {secs_cached:.3}s ({spilled} chunk spills)");
    rows.push(("quant_input_disk_cached", secs_cached, spilled));
    std::fs::remove_dir_all(&dir).ok();

    let fp_secs = rows[0].1;
    let quant_secs = rows[1].1;
    println!(
        "  → quant-input propagation costs {:.2}× fp-input; disk cache costs {:.2}× in-memory",
        quant_secs / fp_secs.max(1e-9),
        secs_cached / quant_secs.max(1e-9)
    );

    let doc = Json::object(vec![
        ("bench", Json::from_str_val("block_recon")),
        ("blocks", Json::from_f64(spec.blocks as f64)),
        ("d", Json::from_f64(spec.d as f64)),
        ("heads", Json::from_f64(spec.heads as f64)),
        ("mlp", Json::from_f64(spec.mlp as f64)),
        ("seq", Json::from_f64(spec.seq as f64)),
        ("calib_seqs", Json::from_f64(spec.calib_seqs as f64)),
        ("iters_per_block", Json::from_f64(iters as f64)),
        (
            "runs",
            Json::Arr(
                rows.iter()
                    .map(|(name, secs, spills)| {
                        Json::object(vec![
                            ("name", Json::from_str_val(name)),
                            ("seconds", Json::from_f64(*secs)),
                            ("chunk_spills", Json::from_f64(*spills as f64)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "ratios",
            Json::object(vec![
                ("quant_vs_fp_input", Json::from_f64(quant_secs / fp_secs.max(1e-9))),
                (
                    "disk_cached_vs_in_memory",
                    Json::from_f64(secs_cached / quant_secs.max(1e-9)),
                ),
            ]),
        ),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_block_recon.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
