//! `cargo bench --bench generate` — decode-latency harness for KV-cached
//! autoregressive generation (DESIGN.md §Generation):
//!
//! * prefill latency at several prompt lengths;
//! * per-token decode cost along one generation: the KV-cached path must
//!   stay O(1) in the generated length while the full-context recompute
//!   baseline grows O(t) — measured as mean per-token latency over the
//!   first 8 vs the last 8 emitted tokens;
//! * the cached-vs-recompute speedup at depth, plus a stream-identity
//!   check (both decoders must sample the exact same tokens);
//! * the continuous-batching throughput curve: aggregate tokens/sec at
//!   1/4/16/64 concurrent sessions through the scheduler
//!   (DESIGN.md §Continuous-Batching) — batching the per-step GEMMs
//!   across sessions must beat decoding them one at a time.
//!
//! Emits machine-readable results to `BENCH_generate.json` at the repo
//! root, alongside the human-readable stdout table.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_WORKERS  worker threads for the fused GEMMs (default all)
//!   FLEXROUND_BENCH_TOKENS   tokens generated for the decode curve (default 96)

use flexround::infer::generate;
use flexround::infer::Engine;
use flexround::sched::{SchedConfig, Scheduler};
use flexround::ser::json::{self, Json};
use flexround::tensor::Tensor;
use flexround::util::pool;
use flexround::util::rng::Pcg32;
use std::time::Instant;

const BLOCKS: usize = 2;
const D: usize = 256;
const HEADS: usize = 4;
const MLP: usize = 512;
const VOCAB: usize = 512;
const BITS: u32 = 4;
const TEMP: f32 = 0.8;
const TOP_K: usize = 32;

fn mean(s: &[f64]) -> f64 {
    if s.is_empty() {
        0.0
    } else {
        s.iter().sum::<f64>() / s.len() as f64
    }
}

fn main() {
    let workers: usize = std::env::var("FLEXROUND_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(pool::default_workers);
    let max_new: usize = std::env::var("FLEXROUND_BENCH_TOKENS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(96)
        .max(20);
    let model = generate::synthetic_lm(BLOCKS, D, HEADS, MLP, 32, VOCAB, BITS, 7)
        .expect("synthetic lm");
    let engine = Engine::new(model, workers);
    println!(
        "== KV-cached generation ({BLOCKS} blocks, d={D}, mlp={MLP}, vocab={VOCAB}, \
         W{BITS}, workers={workers}) =="
    );

    // ---- prefill latency vs prompt length ----
    let mut prefill_rows: Vec<Json> = Vec::new();
    for plen in [8usize, 32, 128] {
        let (_, prompt) = generate::random_prompt(engine.model(), plen, 3).expect("prompt");
        let reps = 5usize;
        let t0 = Instant::now();
        for _ in 0..reps {
            let _ = engine.prefill(&prompt).expect("prefill");
        }
        let ms = 1e3 * t0.elapsed().as_secs_f64() / reps as f64;
        println!("prefill  t={plen:>4}  {ms:9.3} ms");
        prefill_rows.push(Json::object(vec![
            ("prompt_len", Json::from_f64(plen as f64)),
            ("ms", Json::from_f64(ms)),
        ]));
    }

    // ---- per-token decode: cached vs full-context recompute ----
    let (_, prompt) = generate::random_prompt(engine.model(), 8, 3).expect("prompt");

    // cached path: time every decode_step individually
    let (mut state, logits) = engine.prefill(&prompt).expect("prefill");
    let w = logits.shape()[1];
    let rows = logits.shape()[0];
    let mut rng = Pcg32::seeded(7);
    let lv = logits.as_f32().expect("logits");
    let mut tok = generate::sample_token(&lv[(rows - 1) * w..rows * w], TEMP, TOP_K, &mut rng);
    let mut cached_tokens = vec![tok];
    let mut cached_ms: Vec<f64> = Vec::with_capacity(max_new);
    for _ in 1..max_new {
        let row = generate::embed_token(engine.model(), tok).expect("embed");
        let t0 = Instant::now();
        let out = engine.decode_step(&mut state, &row).expect("decode");
        cached_ms.push(1e3 * t0.elapsed().as_secs_f64());
        tok = generate::sample_token(&out, TEMP, TOP_K, &mut rng);
        cached_tokens.push(tok);
    }

    // recompute baseline: forward the whole growing prefix per token
    let dtok = engine.model().in_width().expect("token width");
    let mut rng2 = Pcg32::seeded(7);
    let mut work: Vec<f32> = prompt.as_f32().expect("prompt rows").to_vec();
    let mut t = prompt.shape()[0];
    let mut rec_tokens: Vec<usize> = Vec::with_capacity(max_new);
    let mut recompute_ms: Vec<f64> = Vec::with_capacity(max_new);
    for step in 0..max_new {
        let x = Tensor::from_f32(work.clone(), &[t, dtok]).expect("prefix");
        let t0 = Instant::now();
        let logits = engine.forward_ctx(&x, t).expect("forward_ctx");
        if step > 0 {
            // step 0 is the prefill-equivalent; per-token costs start after
            recompute_ms.push(1e3 * t0.elapsed().as_secs_f64());
        }
        let lv = logits.as_f32().expect("logits");
        let wv = logits.shape()[1];
        let tok = generate::sample_token(&lv[(t - 1) * wv..t * wv], TEMP, TOP_K, &mut rng2);
        rec_tokens.push(tok);
        if step + 1 < max_new {
            work.extend_from_slice(&generate::embed_token(engine.model(), tok).expect("embed"));
            t += 1;
        }
    }
    let streams_match = cached_tokens == rec_tokens;
    // drift guard: the hand-instrumented loop above must emit the same
    // stream as the *shipped* decoder, or the bench measures a stale copy
    let shipped = generate::generate(
        &engine,
        &prompt,
        &generate::GenOpts { max_new, temp: TEMP, top_k: TOP_K, seed: 7 },
    )
    .expect("shipped generate");
    assert_eq!(
        shipped.tokens, cached_tokens,
        "bench decode loop diverged from infer::generate::generate"
    );

    let span = 8usize;
    let c_first = mean(&cached_ms[..span.min(cached_ms.len())]);
    let c_last = mean(&cached_ms[cached_ms.len().saturating_sub(span)..]);
    let r_first = mean(&recompute_ms[..span.min(recompute_ms.len())]);
    let r_last = mean(&recompute_ms[recompute_ms.len().saturating_sub(span)..]);
    println!("decode ({max_new} tokens, temp {TEMP}, top-k {TOP_K}):");
    println!("  cached     first8 {c_first:9.3} ms/tok   last8 {c_last:9.3} ms/tok  (growth {:.2}×)",
             c_last / c_first.max(1e-12));
    println!("  recompute  first8 {r_first:9.3} ms/tok   last8 {r_last:9.3} ms/tok  (growth {:.2}×)",
             r_last / r_first.max(1e-12));
    println!(
        "  → cached is {:.2}× the recompute baseline at depth; streams {}",
        r_last / c_last.max(1e-12),
        if streams_match { "IDENTICAL" } else { "MISMATCHED (bug!)" }
    );

    // ---- continuous batching: aggregate tokens/sec vs concurrent sessions ----
    let sess_new = 16usize;
    let mut sched_rows: Vec<Json> = Vec::new();
    println!("continuous batching (prompt 8, {sess_new} tokens per session):");
    for sessions in [1usize, 4, 16, 64] {
        let model = generate::synthetic_lm(BLOCKS, D, HEADS, MLP, 32, VOCAB, BITS, 7)
            .expect("synthetic lm");
        let cfg = SchedConfig {
            pool_pages: 256,
            page_tokens: 16,
            max_active: sessions,
            prefill_chunk: 32,
            spill_dir: None,
        };
        let mut sched = Scheduler::new(Engine::new(model, workers), cfg).expect("scheduler");
        let prompts: Vec<Tensor> = (0..sessions)
            .map(|i| {
                generate::random_prompt(sched.engine().model(), 8, 30 + i as u64)
                    .expect("prompt")
                    .1
            })
            .collect();
        let t0 = Instant::now();
        for (i, p) in prompts.iter().enumerate() {
            let opts = generate::GenOpts {
                max_new: sess_new,
                temp: TEMP,
                top_k: TOP_K,
                seed: 7 + i as u64,
            };
            sched.submit(p.as_f32().expect("prompt rows").to_vec(), opts).expect("submit");
        }
        let fin = sched.run_all().expect("run_all");
        let secs = t0.elapsed().as_secs_f64();
        let toks: usize = fin.iter().map(|f| f.tokens.len()).sum();
        let tps = toks as f64 / secs.max(1e-12);
        println!(
            "  sessions {sessions:>3}  {toks:>5} tokens in {secs:7.3} s → {tps:9.0} tok/s  \
             ({} steps, peak {} pool pages)",
            sched.steps(),
            sched.occupancy_peaks().1
        );
        sched_rows.push(Json::object(vec![
            ("sessions", Json::from_f64(sessions as f64)),
            ("tokens", Json::from_f64(toks as f64)),
            ("secs", Json::from_f64(secs)),
            ("tokens_per_sec", Json::from_f64(tps)),
            ("steps", Json::from_f64(sched.steps() as f64)),
        ]));
    }

    // ---- BENCH_generate.json at the repo root ----
    let doc = Json::object(vec![
        ("bench", Json::from_str_val("generate")),
        ("workers", Json::from_f64(workers as f64)),
        (
            "model",
            Json::object(vec![
                ("blocks", Json::from_f64(BLOCKS as f64)),
                ("d", Json::from_f64(D as f64)),
                ("heads", Json::from_f64(HEADS as f64)),
                ("mlp", Json::from_f64(MLP as f64)),
                ("vocab", Json::from_f64(VOCAB as f64)),
                ("bits", Json::from_f64(BITS as f64)),
            ]),
        ),
        ("prefill", Json::Arr(prefill_rows)),
        (
            "decode",
            Json::object(vec![
                ("max_new", Json::from_f64(max_new as f64)),
                ("prompt_len", Json::from_f64(8.0)),
                ("cached_ms_per_token_first8", Json::from_f64(c_first)),
                ("cached_ms_per_token_last8", Json::from_f64(c_last)),
                ("recompute_ms_per_token_first8", Json::from_f64(r_first)),
                ("recompute_ms_per_token_last8", Json::from_f64(r_last)),
                ("cached_growth", Json::from_f64(c_last / c_first.max(1e-12))),
                ("recompute_growth", Json::from_f64(r_last / r_first.max(1e-12))),
                (
                    "cached_vs_recompute_at_depth",
                    Json::from_f64(r_last / c_last.max(1e-12)),
                ),
            ]),
        ),
        ("continuous_batching", Json::Arr(sched_rows)),
        ("streams_match", Json::Bool(streams_match)),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_generate.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
