//! `cargo bench --bench paper_figures` — regenerate the data series behind
//! the paper's figures:
//!
//!   Fig 3  — weight-update histograms + grid-shift scatter, first block of
//!            TinyMobileNet and TinyResNet-A (W4)
//!   Fig 4  — grid shifts in a deeper TinyMobileNet block (W4)
//!   Fig 5  — grid shifts in the encoder's first query projection (W8 A8)
//!   Fig 6  — AdaRound vs AdaQuant vs FlexRound shift scatter comparison
//!   Fig 7  — handled by the f7_sample_size sweep (paper_tables / configs)
//!
//! CSV series land in reports/fig_*.csv.

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::report::Reporter;
use flexround::runtime::Pjrt;
use flexround::{quant, Result};
use std::path::Path;
use std::time::Instant;

fn shifts_for(
    sess: &Session,
    rep: &Reporter,
    fig: &str,
    unit_name: &str,
    method: &str,
    bits: u32,
    mode: &str,
    iters: usize,
) -> Result<()> {
    let mut plan = Plan::new(&sess.model.name, method);
    plan.bits_w = bits;
    plan.mode = mode.into();
    plan.drop_p = if mode == "wa" { 0.5 } else { 0.0 };
    plan.iters = iters;
    let r = sess.quantize(&plan)?;
    let (unit, st) = sess
        .model
        .units
        .iter()
        .zip(&r.units)
        .find(|(u, _)| u.name == unit_name)
        .ok_or_else(|| anyhow::anyhow!("no unit {unit_name}"))?;
    for gs in quant::grid_shifts(sess, unit, st)? {
        let id = format!("{fig}_{}_{}_{}_{}", sess.model.name, unit_name, gs.layer, method);
        let rows: Vec<String> = gs.points.iter().map(|(w, d)| format!("{w},{d}")).collect();
        rep.series(&id, "weight,grid_shift", &rows)?;
        println!(
            "  {fig} {}/{}/{} [{method} W{bits}]: shifted {:.2}% aggressive {:.2}% max |Δ| {}",
            sess.model.name, unit_name, gs.layer,
            100.0 * gs.shifted_frac, 100.0 * gs.aggressive_frac, gs.max_shift
        );
    }
    let h = quant::delta_hist(sess, unit, st, 41)?;
    let id = format!("{fig}_hist_{}_{}_{}", sess.model.name, unit_name, method);
    let rows: Vec<String> = (0..h.small_counts.len())
        .map(|i| format!("{},{},{}", h.edges[i], h.small_counts[i], h.large_counts[i]))
        .collect();
    rep.series(&id, "delta_edge,count_small_w,count_large_w", &rows)?;
    Ok(())
}

fn main() {
    let iters: usize = std::env::var("FLEXROUND_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(150);
    let art = Path::new("artifacts");
    let man = match Manifest::load(art) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("paper_figures: skipping ({e}); run `make artifacts` first");
            return;
        }
    };
    let rt = match Pjrt::new(art) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("paper_figures: no PJRT client ({e:#}); skipped");
            return;
        }
    };
    let rep = Reporter::new(Path::new("reports"), true).expect("reports");
    let t0 = Instant::now();

    // Figure 3: first block, MobileNet (large |W|) vs ResNet (small |W|)
    for model in ["tinymobilenet", "tinyresnet_a"] {
        let sess = Session::open(&rt, &man, model).expect("session");
        println!(
            "fig3 {model}: large-|W| fraction {:.3}%",
            100.0 * quant::large_weight_fraction(&sess)
        );
        let unit = sess.model.units[1].name.clone();
        shifts_for(&sess, &rep, "fig3", &unit, "flexround", 4, "w", iters).expect("fig3");
    }

    // Figure 4: a deeper MobileNet block
    {
        let sess = Session::open(&rt, &man, "tinymobilenet").expect("session");
        let deep = sess.model.units[4].name.clone();
        shifts_for(&sess, &rep, "fig4", &deep, "flexround", 4, "w", iters).expect("fig4");
    }

    // Figure 5: encoder first layer (query projection), 8-bit W/A
    {
        let sess = Session::open(&rt, &man, "enc_small").expect("session");
        let first = sess.model.units[0].name.clone();
        shifts_for(&sess, &rep, "fig5", &first, "flexround", 8, "wa", iters).expect("fig5");
    }

    // Figure 6: method comparison on the same first block
    {
        let sess = Session::open(&rt, &man, "tinymobilenet").expect("session");
        let unit = sess.model.units[1].name.clone();
        for method in ["adaround", "adaquant", "flexround"] {
            shifts_for(&sess, &rep, "fig6", &unit, method, 4, "w", iters).expect("fig6");
        }
    }

    println!(
        "== figures done in {:.1}s; {} ==",
        t0.elapsed().as_secs_f64(),
        rt.stats.borrow().summary()
    );
}
