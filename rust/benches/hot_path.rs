//! `cargo bench --bench hot_path` — microbenchmarks of the PTQ hot paths
//! (the §Perf harness; criterion is not vendored, `util::stats::bench` is
//! the timer).
//!
//! Measured:
//!   * recon_step       — one reconstruction Adam step per unit class
//!   * q_advance        — quantized unit forward (literal path)
//!   * fp_advance       — fp unit forward
//!   * calib_gather     — host-side minibatch assembly (pure Rust)
//!   * compile          — PJRT compile latency per artifact class
//!   * substrate micro  — JSON parse, FXT read, RNG, tensor ops

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::runtime::Pjrt;
use flexround::tensor::Tensor;
use flexround::util::rng::Pcg32;
use flexround::util::stats::bench;
use std::path::Path;
use std::time::Duration;

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );

    // ---- substrate micro-benches (no artifacts needed) -----------------
    println!("== substrates ==");
    let json_doc = std::fs::read_to_string("artifacts/manifest.json").unwrap_or_else(|_| {
        r#"{"calib_batch":32,"models":{}}"#.repeat(1)
    });
    println!("{}", bench("json::parse(manifest)", budget, 2_000, || {
        let _ = flexround::ser::json::parse(&json_doc);
    }).report());

    let mut rng = Pcg32::seeded(1);
    let big = Tensor::from_f32((0..1 << 16).map(|i| (i % 97) as f32).collect(), &[256, 256]).unwrap();
    println!("{}", bench("tensor::gather_rows(32 of 256)", budget, 50_000, || {
        let idx = rng.sample_indices(256, 32);
        let _ = big.gather_rows(&idx);
    }).report());
    println!("{}", bench("rng::sample_indices(32 of 1024)", budget, 200_000, || {
        let _ = rng.sample_indices(1024, 32);
    }).report());
    let w: Vec<f32> = (0..4096).map(|i| ((i * 37) % 101) as f32 / 50.0 - 1.0).collect();
    println!("{}", bench("quant::rtn(4096)", budget, 200_000, || {
        let _ = flexround::tensor::rtn(&w, 0.1, 0.0, -8.0, 7.0);
    }).report());

    // ---- artifact-backed benches ---------------------------------------
    let art = Path::new("artifacts");
    let man = match Manifest::load(art) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("hot_path: artifact benches skipped ({e})");
            return;
        }
    };
    let rt = match Pjrt::new(art) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("hot_path: no PJRT client ({e:#}); artifact benches skipped");
            return;
        }
    };

    for model in ["tinymobilenet", "dec_small_lma", "llm_mini"] {
        if !man.models.contains_key(model) {
            continue;
        }
        println!("== {model} ==");
        if let Err(e) = bench_model(&man, &rt, model, budget) {
            println!("  {model}: skipped ({e:#})");
        }
    }
    println!("runtime: {}", rt.stats.borrow().summary());
}

fn bench_model(
    man: &Manifest,
    rt: &Pjrt,
    model: &str,
    budget: Duration,
) -> anyhow::Result<()> {
    {
        let sess = Session::open(rt, man, model)?;
        let calib = sess.dataset("calib_x")?.clone();
        let b = sess.model.calib_batch;
        let x0 = calib.slice_rows(0, b)?;
        let chunks = sess.first_unit_inputs(&x0)?;

        // fp advance on the first unit (its input is the chain input)
        let unit = &sess.model.units[0];
        sess.advance_fp(unit, &chunks)?; // fail fast before timing
        println!("{}", bench(&format!("fp_advance[{}]", unit.name), budget, 10_000, || {
            let _ = sess.advance_fp(unit, &chunks);
        }).report());

        // one-unit recon step throughput via a 1-iteration quantize on a
        // truncated calibration set
        let method = if sess.model.methods_w.iter().any(|m| m == "flexround")
            || sess.model.methods_wa.iter().any(|m| m == "flexround") {
            "flexround"
        } else {
            "adaround"
        };
        let mode = if sess.model.methods_w.iter().any(|m| m == method) { "w" } else { "wa" };
        let mut plan = Plan::new(model, method);
        plan.mode = mode.into();
        plan.bits_w = *sess.model.bits_w.iter().max().unwrap();
        plan.iters = 8;
        plan.calib_n = b;
        let t0 = std::time::Instant::now();
        let r = sess.quantize(&plan)?;
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "recon_step[{model}:{method}:{mode}]      {:>7} steps  {:>10.3}ms/step  ({} units)",
            r.recon_steps,
            1e3 * r.recon_seconds / r.recon_steps.max(1) as f64,
            r.units.len()
        );
        println!(
            "quantize_total[{model}]                  wall {dt:.2}s  recon {:.2}s  overhead {:.1}%",
            r.recon_seconds,
            100.0 * (dt - r.recon_seconds).max(0.0) / dt
        );

        // q advance with learned params
        let st = &r.units[sess.model.units.iter().position(|u| u.name == unit.name).unwrap()];
        sess.advance_q(unit, st, mode, &chunks)?; // fail fast before timing
        println!("{}", bench(&format!("q_advance[{}:{}]", unit.name, method), budget, 10_000, || {
            let _ = sess.advance_q(unit, st, mode, &chunks);
        }).report());
    }
    Ok(())
}
