//! `cargo bench --bench paper_tables` — regenerate every table of the
//! paper's evaluation (analog workloads; see DESIGN.md per-experiment index)
//! and time each sweep.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_ITERS   recon iterations per unit   (default 120)
//!   FLEXROUND_BENCH_CALIB   calibration samples         (default 256)
//!   FLEXROUND_BENCH_ONLY    comma-separated sweep ids to run
//!
//! Full-fidelity runs go through `flexround sweep --config configs/<id>.toml`
//! without the overrides.

use flexround::config::Config;
use flexround::manifest::Manifest;
use flexround::report::Reporter;
use flexround::runtime::Pjrt;
use std::path::Path;
use std::time::Instant;

const SWEEPS: &[&str] = &[
    "t1_ablation",
    "t2_weight_only",
    "t3_weight_act",
    "t4_nlu",
    "t5_nlg",
    "t6_lora",
    "t7_llm",
    "t8_alt_pretrained",
    "t9_alt_wa",
    "t10_cle_ahb",
    "t11_combo",
    "t12_span",
    "t21_llm_weight_only",
];

fn main() {
    let iters: usize = std::env::var("FLEXROUND_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);
    let calib: usize = std::env::var("FLEXROUND_BENCH_CALIB")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(256);
    let only: Option<Vec<String>> = std::env::var("FLEXROUND_BENCH_ONLY")
        .ok()
        .map(|s| s.split(',').map(|t| t.trim().to_string()).collect());

    let art = Path::new("artifacts");
    let man = match Manifest::load(art) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("paper_tables: skipping ({e}); run `make artifacts` first");
            return;
        }
    };
    let rt = match Pjrt::new(art) {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("paper_tables: no PJRT client ({e:#}); skipped");
            return;
        }
    };
    let rep = Reporter::new(Path::new("reports"), true).expect("reports dir");

    println!("== paper tables (iters={iters}, calib={calib}) ==");
    let mut total = 0.0;
    for id in SWEEPS {
        if let Some(only) = &only {
            if !only.iter().any(|o| o == id) {
                continue;
            }
        }
        let cfg_path = format!("configs/{id}.toml");
        if !Path::new(&cfg_path).exists() {
            eprintln!("  {id}: missing config, skipped");
            continue;
        }
        let mut cfg = Config::new();
        cfg.load_file(Path::new(&cfg_path)).expect("config");
        cfg.set_override(&format!("sweep.iters={iters}")).unwrap();
        cfg.set_override(&format!("sweep.calib_n={calib}")).unwrap();
        let t0 = Instant::now();
        match flexround::sweep::run_sweep(&cfg, &man, &rt, &rep) {
            Ok(()) => {
                let dt = t0.elapsed().as_secs_f64();
                total += dt;
                println!("  {id:<22} {dt:>8.1}s  → reports/{id}.md");
            }
            Err(e) => println!("  {id:<22} FAILED: {e:#}"),
        }
    }
    println!("== total {total:.1}s; runtime {} ==", rt.stats.borrow().summary());
}
