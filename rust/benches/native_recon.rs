//! `cargo bench --bench native_recon` — the native reconstruction engine's
//! perf harness (EXPERIMENTS.md §Perf: native vs PJRT per-unit
//! reconstruction time), plus a per-scheme reconstruction-time comparison
//! (FlexRound vs AdaRound through the `Rounding` trait — DESIGN.md
//! §Rounding-Schemes).
//!
//! Needs no artifacts: synthetic units are generated in-process.  When real
//! artifacts *are* present and the build carries working PJRT bindings, a
//! comparison row times the AOT reconstruction step on the same hardware.
//!
//! Emits machine-readable results to `BENCH_native_recon.json` at the repo
//! root, alongside the human-readable stdout lines.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_MS      per-measurement budget in ms (default 1500)
//!   FLEXROUND_BENCH_WORKERS worker threads for the pool rows (default all)

use flexround::recon::rounding::{beta_schedule, scheme_for};
use flexround::recon::{self, LayerDef};
use flexround::ser::json::{self, Json};
use flexround::util::pool;
use flexround::util::rng::Pcg32;
use flexround::util::stats::bench;
use std::time::Duration;

/// (rows, cols, calib rows, batch) — sized like the repo's unit classes:
/// a CNN block row, a transformer projection, and an MLP-scale layer.
const SIZES: [(usize, usize, usize, usize); 3] =
    [(32, 64, 256, 32), (128, 128, 256, 32), (256, 512, 512, 64)];

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );
    let workers: usize = std::env::var("FLEXROUND_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(pool::default_workers);
    let scheme = scheme_for("flexround").expect("flexround scheme");

    println!("== native reconstruction (workers={workers}) ==");
    for &(r, c, n, b) in &SIZES {
        let p = recon::synthetic_problem(r, c, n, 4, 7);
        let slots = recon::synthetic_slots();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let mut rng = Pcg32::seeded(7);

        // one full Adam step: minibatch gather + fwd + bwd + update
        let mut params = p.params.clone();
        let mut opt = flexround::recon::Adam::new(&params);
        let mut t = 0usize;
        println!("{}", bench(
            &format!("native recon_step[{r}x{c}, batch {b}]"),
            budget,
            10_000,
            || {
                t += 1;
                let idx = rng.sample_indices(n, b);
                let xb = p.x.gather_rows(&idx).expect("gather");
                let yb = p.y.gather_rows(&idx).expect("gather");
                let beta = beta_schedule(t, 10_000);
                let (_, grads) = recon::loss_and_grads(
                    scheme, &layers, &slots, &params, &xb, &yb, p.qmin, p.qmax, beta, workers,
                ).expect("step");
                opt.step(t, 3e-3, &p.entries, &mut params, &grads).expect("adam");
            },
        ).report());

        // quantized forward over the full calibration set
        println!("{}", bench(
            &format!("native q_forward[{r}x{c}, {n} rows]"),
            budget,
            10_000,
            || {
                let _ = recon::unit_forward_q(
                    scheme, &layers, &slots, &p.params, p.qmin, p.qmax, &p.x, workers,
                ).expect("fwd");
            },
        ).report());

        // fake-quant kernel alone (the Ŵ materialization)
        println!("{}", bench(
            &format!("native fq[{r}x{c}]"),
            budget,
            50_000,
            || {
                let _ = recon::fq_forward(
                    &p.w, &p.params[0], Some(&p.params[1]), Some(&p.params[2]),
                    Some(&p.params[3]), &p.params[4], p.qmin, p.qmax,
                ).expect("fq");
            },
        ).report());
    }

    // end-to-end: the selftest problem per rounding scheme, timed once per
    // worker count.  Same size and iteration budget for every scheme, so
    // the seconds column is a direct per-step cost comparison; each scheme
    // trains its own parameter pack (FlexRound: s1/s2/s3/s4; AdaRound: V).
    let mut rows: Vec<(String, &'static str, usize, f64, f64, f64)> = Vec::new();
    for w in [1, workers] {
        for method in ["flexround", "adaround"] {
            let (p, slots, lr) = if method == "adaround" {
                (
                    recon::synthetic_problem_adaround(64, 128, 256, 3, 7),
                    recon::synthetic_slots_adaround(),
                    1e-2,
                )
            } else {
                (recon::synthetic_problem(64, 128, 256, 3, 7), recon::synthetic_slots(), 4e-3)
            };
            let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
            let cfg = recon::ReconSettings {
                iters: 100,
                lr,
                batch: 32,
                qmin: p.qmin,
                qmax: p.qmax,
                workers: w,
                verbose: false,
                tag: format!("bench/{method}"),
                scheme: scheme_for(method).expect("scheme"),
            };
            let mut rng = Pcg32::seeded(7);
            let t0 = std::time::Instant::now();
            let res = recon::reconstruct_unit(
                &layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng,
            ).expect("recon");
            let secs = t0.elapsed().as_secs_f64();
            println!(
                "native reconstruct_unit[64x128, 100 iters, workers={w}, {method:<9}]  \
                 {:>8.1}ms  (loss {:.5} → {:.5})",
                1e3 * secs,
                res.first_loss,
                res.final_loss,
            );
            rows.push((
                format!("reconstruct_unit_{method}_w{w}"),
                method,
                w,
                secs,
                res.first_loss,
                res.final_loss,
            ));
        }
    }

    let doc = Json::object(vec![
        ("bench", Json::from_str_val("native_recon")),
        ("rows_cols", Json::from_str_val("64x128")),
        ("calib_rows", Json::from_f64(256.0)),
        ("iters", Json::from_f64(100.0)),
        (
            "runs",
            Json::Arr(
                rows.iter()
                    .map(|(name, method, w, secs, first, last)| {
                        Json::object(vec![
                            ("name", Json::from_str_val(name)),
                            ("scheme", Json::from_str_val(method)),
                            ("workers", Json::from_f64(*w as f64)),
                            ("seconds", Json::from_f64(*secs)),
                            ("first_loss", Json::from_f64(*first)),
                            ("final_loss", Json::from_f64(*last)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_native_recon.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }

    pjrt_comparison(budget);
}

/// PJRT per-unit recon-step timing on the same machine, when artifacts and
/// real bindings exist (EXPERIMENTS.md §Perf, native-vs-PJRT table).
#[cfg(feature = "pjrt")]
fn pjrt_comparison(_budget: Duration) {
    use flexround::coordinator::{Plan, Session};
    use flexround::manifest::Manifest;
    use flexround::runtime::Pjrt;
    use std::path::Path;

    let art = Path::new("artifacts");
    let Ok(man) = Manifest::load(art) else {
        println!("pjrt comparison: no artifacts (native-only run)");
        return;
    };
    let Ok(rt) = Pjrt::new(art) else {
        println!("pjrt comparison: no PJRT client (stub build; native-only run)");
        return;
    };
    for model in ["tinymobilenet", "dec_small_lma"] {
        if !man.models.contains_key(model) {
            continue;
        }
        let run = || -> flexround::Result<()> {
            let sess = Session::open(&rt, &man, model)?;
            let b = sess.model.calib_batch;
            let mut plan = Plan::new(model, "flexround");
            if !sess.model.methods_w.iter().any(|m| m == "flexround") {
                plan.mode = "wa".into();
            }
            plan.bits_w = *sess.model.bits_w.iter().max().unwrap_or(&8);
            plan.iters = 8;
            plan.calib_n = b;
            let r = sess.quantize(&plan)?;
            println!(
                "pjrt recon_step[{model}]  {:>10.3}ms/step  ({} units)",
                1e3 * r.recon_seconds / r.recon_steps.max(1) as f64,
                r.units.len()
            );
            Ok(())
        };
        if let Err(e) = run() {
            println!("pjrt recon_step[{model}]: skipped ({e:#})");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_comparison(_budget: Duration) {
    println!("pjrt comparison: built without the `pjrt` feature (native-only run)");
}
