//! `cargo bench --bench native_recon` — the native reconstruction engine's
//! perf harness (EXPERIMENTS.md §Perf: native vs PJRT per-unit
//! reconstruction time).
//!
//! Needs no artifacts: synthetic units are generated in-process.  When real
//! artifacts *are* present and the build carries working PJRT bindings, a
//! comparison row times the AOT reconstruction step on the same hardware.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_MS      per-measurement budget in ms (default 1500)
//!   FLEXROUND_BENCH_WORKERS worker threads for the pool rows (default all)

use flexround::recon::{self, LayerDef};
use flexround::util::pool;
use flexround::util::rng::Pcg32;
use flexround::util::stats::bench;
use std::time::Duration;

/// (rows, cols, calib rows, batch) — sized like the repo's unit classes:
/// a CNN block row, a transformer projection, and an MLP-scale layer.
const SIZES: [(usize, usize, usize, usize); 3] =
    [(32, 64, 256, 32), (128, 128, 256, 32), (256, 512, 512, 64)];

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(1500),
    );
    let workers: usize = std::env::var("FLEXROUND_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(pool::default_workers);

    println!("== native reconstruction (workers={workers}) ==");
    for &(r, c, n, b) in &SIZES {
        let p = recon::synthetic_problem(r, c, n, 4, 7);
        let slots = recon::synthetic_slots();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let mut rng = Pcg32::seeded(7);

        // one full Adam step: minibatch gather + fwd + bwd + update
        let mut params = p.params.clone();
        let mut opt = flexround::recon::Adam::new(&params);
        let mut t = 0usize;
        println!("{}", bench(
            &format!("native recon_step[{r}x{c}, batch {b}]"),
            budget,
            10_000,
            || {
                t += 1;
                let idx = rng.sample_indices(n, b);
                let xb = p.x.gather_rows(&idx).expect("gather");
                let yb = p.y.gather_rows(&idx).expect("gather");
                let (_, grads) = recon::loss_and_grads(
                    &layers, &slots, &params, &xb, &yb, p.qmin, p.qmax, workers,
                ).expect("step");
                opt.step(t, 3e-3, &p.entries, &mut params, &grads).expect("adam");
            },
        ).report());

        // quantized forward over the full calibration set
        println!("{}", bench(
            &format!("native q_forward[{r}x{c}, {n} rows]"),
            budget,
            10_000,
            || {
                let _ = recon::unit_forward_q(
                    &layers, &slots, &p.params, p.qmin, p.qmax, &p.x, workers,
                ).expect("fwd");
            },
        ).report());

        // fake-quant kernel alone (the Ŵ materialization)
        println!("{}", bench(
            &format!("native fq[{r}x{c}]"),
            budget,
            50_000,
            || {
                let _ = recon::fq_forward(
                    &p.w, &p.params[0], Some(&p.params[1]), Some(&p.params[2]),
                    Some(&p.params[3]), &p.params[4], p.qmin, p.qmax,
                ).expect("fq");
            },
        ).report());
    }

    // end-to-end: the selftest problem, timed once per worker count
    for w in [1, workers] {
        let t0 = std::time::Instant::now();
        let p = recon::synthetic_problem(64, 128, 256, 3, 7);
        let slots = recon::synthetic_slots();
        let layers = [LayerDef { name: "fc", w: &p.w, bias: None, relu_after: false }];
        let cfg = recon::ReconSettings {
            iters: 100,
            lr: 4e-3,
            batch: 32,
            qmin: p.qmin,
            qmax: p.qmax,
            workers: w,
            verbose: false,
            tag: "bench".to_string(),
        };
        let mut rng = Pcg32::seeded(7);
        let res = recon::reconstruct_unit(
            &layers, &slots, &p.entries, &p.params, &p.x, &p.y, &cfg, &mut rng,
        ).expect("recon");
        println!(
            "native reconstruct_unit[64x128, 100 iters, workers={w}]  {:>8.1}ms  \
             (loss {:.5} → {:.5})",
            1e3 * t0.elapsed().as_secs_f64(),
            res.first_loss,
            res.final_loss,
        );
    }

    pjrt_comparison(budget);
}

/// PJRT per-unit recon-step timing on the same machine, when artifacts and
/// real bindings exist (EXPERIMENTS.md §Perf, native-vs-PJRT table).
#[cfg(feature = "pjrt")]
fn pjrt_comparison(_budget: Duration) {
    use flexround::coordinator::{Plan, Session};
    use flexround::manifest::Manifest;
    use flexround::runtime::Pjrt;
    use std::path::Path;

    let art = Path::new("artifacts");
    let Ok(man) = Manifest::load(art) else {
        println!("pjrt comparison: no artifacts (native-only run)");
        return;
    };
    let Ok(rt) = Pjrt::new(art) else {
        println!("pjrt comparison: no PJRT client (stub build; native-only run)");
        return;
    };
    for model in ["tinymobilenet", "dec_small_lma"] {
        if !man.models.contains_key(model) {
            continue;
        }
        let run = || -> flexround::Result<()> {
            let sess = Session::open(&rt, &man, model)?;
            let b = sess.model.calib_batch;
            let mut plan = Plan::new(model, "flexround");
            if !sess.model.methods_w.iter().any(|m| m == "flexround") {
                plan.mode = "wa".into();
            }
            plan.bits_w = *sess.model.bits_w.iter().max().unwrap_or(&8);
            plan.iters = 8;
            plan.calib_n = b;
            let r = sess.quantize(&plan)?;
            println!(
                "pjrt recon_step[{model}]  {:>10.3}ms/step  ({} units)",
                1e3 * r.recon_seconds / r.recon_steps.max(1) as f64,
                r.units.len()
            );
            Ok(())
        };
        if let Err(e) = run() {
            println!("pjrt recon_step[{model}]: skipped ({e:#})");
        }
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_comparison(_budget: Duration) {
    println!("pjrt comparison: built without the `pjrt` feature (native-only run)");
}
