//! `cargo bench --bench infer` — perf harness for the quantized inference
//! engine (DESIGN.md §Inference-and-Serving):
//!
//! * fused dequant-GEMM vs the dequantize-then-matmul baseline (and the
//!   scalar reference kernel) on a 1024×1024 unit at W4/W8, micro-batch
//!   sizes 1 and 8;
//! * micro-batched vs unbatched serve throughput on a 2-unit 512×512 model.
//!
//! Emits machine-readable results to `BENCH_infer.json` at the repo root
//! (the infer bench trajectory), alongside the human-readable stdout table.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_MS       per-measurement budget in ms (default 800)
//!   FLEXROUND_BENCH_WORKERS  worker threads for the fused kernel (default all)

use flexround::infer::{drive, kernels, synthetic_model, BatchPolicy, Engine, PackedMatrix};
use flexround::ser::json::{self, Json};
use flexround::tensor::Tensor;
use flexround::util::pool;
use flexround::util::rng::Pcg32;
use flexround::util::stats::{bench, BenchResult};
use std::time::Duration;

const GEMM_DIM: usize = 1024;

fn gemm_json(r: &BenchResult, bits: u32, batch: usize) -> Json {
    Json::object(vec![
        ("name", Json::from_str_val(&r.name)),
        ("bits", Json::from_f64(bits as f64)),
        ("batch", Json::from_f64(batch as f64)),
        ("rows", Json::from_f64(GEMM_DIM as f64)),
        ("cols", Json::from_f64(GEMM_DIM as f64)),
        ("iters", Json::from_f64(r.iters as f64)),
        ("mean_ms", Json::from_f64(r.mean * 1e3)),
        ("p50_ms", Json::from_f64(r.p50 * 1e3)),
        ("p95_ms", Json::from_f64(r.p95 * 1e3)),
        ("min_ms", Json::from_f64(r.min * 1e3)),
    ])
}

fn bench_matrix(bits: u32, seed: u64) -> PackedMatrix {
    let model = synthetic_model(1, GEMM_DIM, bits, seed).expect("synthetic model");
    model.units[0].layers[0].mat.clone()
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(800),
    );
    let workers: usize = std::env::var("FLEXROUND_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(pool::default_workers);

    let mut gemm_rows: Vec<Json> = Vec::new();
    let mut speedups: Vec<(String, Json)> = Vec::new();

    println!("== fused dequant-GEMM vs dequantize-then-matmul ({GEMM_DIM}×{GEMM_DIM}, workers={workers}) ==");
    let mut rng = Pcg32::seeded(7);
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        for batch in [1usize, 8] {
            let x = Tensor::from_f32(
                (0..batch * GEMM_DIM).map(|_| rng.next_normal()).collect(),
                &[batch, GEMM_DIM],
            )
            .expect("activations");
            let fused = bench(
                &format!("fused_w{bits}_b{batch}"),
                budget,
                10_000,
                || {
                    let _ = kernels::gemm_fused(&x, &m, workers).expect("fused gemm");
                },
            );
            println!("{}", fused.report());
            let dequant = bench(
                &format!("dequant_matmul_w{bits}_b{batch}"),
                budget,
                10_000,
                || {
                    let _ = kernels::dequant_matmul(&x, &m).expect("dequant gemm");
                },
            );
            println!("{}", dequant.report());
            let s = dequant.p50 / fused.p50.max(1e-12);
            println!("  → fused is {s:.2}× the dequantize-then-matmul baseline");
            speedups.push((
                format!("w{bits}_b{batch}_{GEMM_DIM}x{GEMM_DIM}"),
                Json::from_f64(s),
            ));
            gemm_rows.push(gemm_json(&fused, bits, batch));
            gemm_rows.push(gemm_json(&dequant, bits, batch));
        }
    }

    // ---- serve throughput: micro-batched vs unbatched ----
    let serve_units = 2usize;
    let serve_width = 512usize;
    let requests = 1024usize;
    let clients = 8usize;
    println!("== serve throughput ({serve_units}× {serve_width}×{serve_width} W4, {requests} requests, {clients} clients) ==");
    let mut rng = Pcg32::seeded(11);
    let rows: Vec<Vec<f32>> = (0..requests)
        .map(|_| (0..serve_width).map(|_| rng.next_normal()).collect())
        .collect();
    let mk_engine = || {
        Engine::new(
            synthetic_model(serve_units, serve_width, 4, 11).expect("serve model"),
            workers,
        )
    };
    let batched_policy = BatchPolicy { max_batch: 32, deadline: Duration::from_millis(1) };
    let (b_secs, b_stats) =
        drive(mk_engine(), batched_policy, rows.clone(), clients).expect("batched drive");
    let unbatched_policy = BatchPolicy { max_batch: 1, deadline: Duration::ZERO };
    let (u_secs, u_stats) =
        drive(mk_engine(), unbatched_policy, rows, clients).expect("unbatched drive");
    let b_rps = b_stats.requests as f64 / b_secs.max(1e-9);
    let u_rps = u_stats.requests as f64 / u_secs.max(1e-9);
    println!(
        "batched   {b_rps:>10.0} rows/s  ({} batches, mean {:.1} rows/batch)",
        b_stats.batches,
        b_stats.mean_batch()
    );
    println!("unbatched {u_rps:>10.0} rows/s  ({} batches)", u_stats.batches);
    println!("  → micro-batching speedup {:.2}×", b_rps / u_rps.max(1e-9));

    // ---- BENCH_infer.json at the repo root ----
    let doc = Json::object(vec![
        ("bench", Json::from_str_val("infer")),
        ("workers", Json::from_f64(workers as f64)),
        ("gemm", Json::Arr(gemm_rows)),
        ("fused_vs_dequant_speedup", Json::Obj(speedups.into_iter().collect())),
        (
            "serve",
            Json::object(vec![
                ("units", Json::from_f64(serve_units as f64)),
                ("width", Json::from_f64(serve_width as f64)),
                ("bits", Json::from_f64(4.0)),
                ("requests", Json::from_f64(requests as f64)),
                ("clients", Json::from_f64(clients as f64)),
                ("batched_rows_per_s", Json::from_f64(b_rps)),
                ("batched_mean_batch", Json::from_f64(b_stats.mean_batch())),
                ("unbatched_rows_per_s", Json::from_f64(u_rps)),
                ("speedup", Json::from_f64(b_rps / u_rps.max(1e-9))),
            ]),
        ),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_infer.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
