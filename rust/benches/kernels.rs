//! `cargo bench --bench kernels` — perf harness for the unified `linalg`
//! kernel core (DESIGN.md §Compute-Kernels):
//!
//! * blocked `matmul_nt` (serial tile loop, and under the parallel
//!   dispatch policy) vs the retained naive triple-loop oracle at
//!   1024×1024·1024ᵀ;
//! * the scalar tiles vs the runtime-detected SIMD arm on the same serial
//!   1024³ NT problem, plus the batch-1 `gemv_nt` core at serving shapes
//!   (hidden→hidden, up-/down-projection) — the acceptance numbers for the
//!   ISA dispatch layer;
//! * the fused dequant-GEMM panel kernel vs PR 2's rowwise fused kernel at
//!   1024×1024, W4/W8, micro-batch 8;
//! * the f32 panel kernel vs the integer-domain fused GEMM
//!   (`gemm_fused_int`) at W4/W8, batch 1 and 8;
//! * the batch-1 gemv decode path (what `Engine::decode_step` pays per
//!   projection) at 1024×1024, W4/W8;
//! * the in-register weight decode (`simd::unpack_codes_*`) vs the scalar
//!   word walk, whole-matrix decode throughput at W4/W8;
//! * the i16-madd fused route vs the i32 integer route vs the f32 panel
//!   kernel (`gemm_fused_int_route`), W4/W8 × batch {1, 8} — the batch-1
//!   W4A8 row is the acceptance number for the madd PR.
//!
//! Emits machine-readable results to `BENCH_kernels.json` at the repo root,
//! alongside the human-readable stdout table.
//!
//! Environment knobs:
//!   FLEXROUND_BENCH_MS       per-measurement budget in ms (default 800)
//!   FLEXROUND_BENCH_WORKERS  worker threads for parallel dispatch (default all)
//!   FLEXROUND_FORCE_SCALAR   nonempty (≠"0") pins the *active* arm to the
//!                            scalar tiles; the ISA section still pits both
//!                            arms against each other via explicit pins
//!   FLEXROUND_FORCE_NO_MADD  nonempty (≠"0") disables the i16-madd auto
//!                            route; the madd section still pins it
//!                            explicitly via `IntRoute::Madd`

use flexround::infer::{kernels, synthetic_model, PackedMatrix};
use flexround::linalg::{self, simd, Dispatch, Isa};
use flexround::ser::json::{self, Json};
use flexround::tensor::Tensor;
use flexround::util::pool;
use flexround::util::rng::Pcg32;
use flexround::util::stats::{bench, BenchResult};
use std::time::Duration;

const DIM: usize = 1024;

fn ms(r: &BenchResult) -> Json {
    Json::object(vec![
        ("iters", Json::from_f64(r.iters as f64)),
        ("mean_ms", Json::from_f64(r.mean * 1e3)),
        ("p50_ms", Json::from_f64(r.p50 * 1e3)),
        ("min_ms", Json::from_f64(r.min * 1e3)),
    ])
}

fn bench_matrix(bits: u32, seed: u64) -> PackedMatrix {
    let model = synthetic_model(1, DIM, bits, seed).expect("synthetic model");
    model.units[0].layers[0].mat.clone()
}

fn main() {
    let budget = Duration::from_millis(
        std::env::var("FLEXROUND_BENCH_MS").ok().and_then(|s| s.parse().ok()).unwrap_or(800),
    );
    let workers: usize = std::env::var("FLEXROUND_BENCH_WORKERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or_else(pool::default_workers);

    let mut rng = Pcg32::seeded(3);

    // ---- blocked vs naive f32 matmul_nt at 1024² ----
    println!("== blocked linalg::gemm_nt vs naive triple loop ({DIM}×{DIM}·{DIM}ᵀ, workers={workers}) ==");
    let a = Tensor::from_f32((0..DIM * DIM).map(|_| rng.next_normal()).collect(), &[DIM, DIM])
        .expect("a");
    let b = Tensor::from_f32((0..DIM * DIM).map(|_| rng.next_normal()).collect(), &[DIM, DIM])
        .expect("b");
    let (av, bv) = (a.as_f32().expect("f32"), b.as_f32().expect("f32"));
    let naive = bench("matmul_nt_naive", budget, 5, || {
        let _ = linalg::gemm_nt_ref(av, bv, DIM, DIM, DIM);
    });
    println!("{}", naive.report());
    let blocked = bench("matmul_nt_blocked_serial", budget, 50, || {
        let _ = a.matmul_nt_with(&b, &Dispatch::serial()).expect("blocked");
    });
    println!("{}", blocked.report());
    let blocked_par = bench("matmul_nt_blocked_par", budget, 200, || {
        let _ = a.matmul_nt_with(&b, &Dispatch::new(workers)).expect("blocked par");
    });
    println!("{}", blocked_par.report());
    let s_serial = naive.p50 / blocked.p50.max(1e-12);
    let s_par = naive.p50 / blocked_par.p50.max(1e-12);
    println!("  → blocked serial is {s_serial:.2}× the naive loop; parallel {s_par:.2}×");
    let matmul_json = Json::object(vec![
        ("dim", Json::from_f64(DIM as f64)),
        ("naive", ms(&naive)),
        ("blocked_serial", ms(&blocked)),
        ("blocked_parallel", ms(&blocked_par)),
        ("speedup_blocked_vs_naive", Json::from_f64(s_serial)),
        ("speedup_parallel_vs_naive", Json::from_f64(s_par)),
    ]);

    // ---- scalar tiles vs detected SIMD arm, serial 1024³ NT ----
    let vec_isa = Isa::detect();
    println!(
        "== scalar tiles vs detected SIMD arm ({}) — serial {DIM}×{DIM}·{DIM}ᵀ ==",
        vec_isa.label()
    );
    let scalar_nt = bench("matmul_nt_scalar", budget, 50, || {
        let _ = a.matmul_nt_with(&b, &Dispatch::serial().with_isa(Isa::Scalar)).expect("scalar");
    });
    println!("{}", scalar_nt.report());
    let simd_nt = bench(&format!("matmul_nt_{}", vec_isa.label()), budget, 50, || {
        let _ = a.matmul_nt_with(&b, &Dispatch::serial().with_isa(vec_isa)).expect("simd");
    });
    println!("{}", simd_nt.report());
    let s_simd = scalar_nt.p50 / simd_nt.p50.max(1e-12);
    println!("  → {} arm is {s_simd:.2}× the scalar tiles (serial NT)", vec_isa.label());
    let isa_json = Json::object(vec![
        ("dim", Json::from_f64(DIM as f64)),
        ("isa", Json::from_str_val(vec_isa.label())),
        ("scalar_serial", ms(&scalar_nt)),
        ("simd_serial", ms(&simd_nt)),
        ("speedup_simd_vs_scalar", Json::from_f64(s_simd)),
    ]);

    // ---- gemv_nt core at serving shapes, scalar vs SIMD ----
    println!("== gemv_nt core, scalar vs {} (batch-1 serving shapes) ==", vec_isa.label());
    let mut gemv_isa_rows: Vec<Json> = Vec::new();
    for (k, r) in [(DIM, DIM), (DIM, 4 * DIM), (4 * DIM, DIM)] {
        let x: Vec<f32> = (0..k).map(|_| rng.next_normal()).collect();
        let w: Vec<f32> = (0..r * k).map(|_| rng.next_normal()).collect();
        let mut out = vec![0.0f32; r];
        let scalar_g = bench(&format!("gemv_nt_scalar_{k}x{r}"), budget, 10_000, || {
            out.iter_mut().for_each(|o| *o = 0.0);
            simd::gemv_nt(Isa::Scalar, &x, &w, k, r, &mut out);
        });
        println!("{}", scalar_g.report());
        let mut out = vec![0.0f32; r];
        let simd_g = bench(&format!("gemv_nt_{}_{k}x{r}", vec_isa.label()), budget, 10_000, || {
            out.iter_mut().for_each(|o| *o = 0.0);
            simd::gemv_nt(vec_isa, &x, &w, k, r, &mut out);
        });
        println!("{}", simd_g.report());
        let s = scalar_g.p50 / simd_g.p50.max(1e-12);
        println!("  → {s:.2}× at {k}→{r}");
        gemv_isa_rows.push(Json::object(vec![
            ("k", Json::from_f64(k as f64)),
            ("r", Json::from_f64(r as f64)),
            ("scalar", ms(&scalar_g)),
            ("simd", ms(&simd_g)),
            ("speedup_simd_vs_scalar", Json::from_f64(s)),
        ]));
    }

    // ---- fused panel kernel vs rowwise fused at 1024², W4/W8 ----
    let batch = 8usize;
    println!("== fused panel kernel vs rowwise fused ({DIM}×{DIM}, batch {batch}) ==");
    let mut fused_rows: Vec<Json> = Vec::new();
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        let x = Tensor::from_f32(
            (0..batch * DIM).map(|_| rng.next_normal()).collect(),
            &[batch, DIM],
        )
        .expect("activations");
        let rowwise = bench(&format!("fused_rowwise_w{bits}"), budget, 2_000, || {
            let _ = kernels::gemm_fused_rowwise(&x, &m).expect("rowwise");
        });
        println!("{}", rowwise.report());
        let panel = bench(&format!("fused_panel_w{bits}"), budget, 2_000, || {
            let _ = kernels::gemm_fused(&x, &m, 1).expect("panel");
        });
        println!("{}", panel.report());
        let panel_par = bench(&format!("fused_panel_par_w{bits}"), budget, 5_000, || {
            let _ = kernels::gemm_fused(&x, &m, workers).expect("panel par");
        });
        println!("{}", panel_par.report());
        let s = rowwise.p50 / panel.p50.max(1e-12);
        println!("  → panel kernel is {s:.2}× the rowwise kernel (serial, W{bits})");
        fused_rows.push(Json::object(vec![
            ("bits", Json::from_f64(bits as f64)),
            ("batch", Json::from_f64(batch as f64)),
            ("rowwise", ms(&rowwise)),
            ("panel_serial", ms(&panel)),
            ("panel_parallel", ms(&panel_par)),
            ("speedup_panel_vs_rowwise", Json::from_f64(s)),
        ]));
    }

    // ---- f32 panel vs integer-domain fused GEMM, W4/W8 × batch {1, 8} ----
    println!("== f32 panel vs integer-domain fused gemm ({DIM}×{DIM}) ==");
    let mut int_rows: Vec<Json> = Vec::new();
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        for batch in [1usize, 8] {
            // f32 side: generic (non-integral) activations on the panel path
            let xf = Tensor::from_f32(
                (0..batch * DIM).map(|_| rng.next_normal()).collect(),
                &[batch, DIM],
            )
            .expect("f32 activations");
            // integer side: exact 8-bit-magnitude integer activations — the
            // quantized-activation serving shape the integer domain targets
            let xi = Tensor::from_f32(
                (0..batch * DIM).map(|_| rng.below(255) as f32 - 127.0).collect(),
                &[batch, DIM],
            )
            .expect("integer activations");
            let f32_side = bench(&format!("fused_f32_w{bits}_b{batch}"), budget, 2_000, || {
                let _ = kernels::gemm_fused(&xf, &m, 1).expect("f32 fused");
            });
            println!("{}", f32_side.report());
            let int_side = bench(&format!("fused_int_w{bits}_b{batch}"), budget, 2_000, || {
                let _ = kernels::gemm_fused_int(&xi, &m, 1).expect("int fused");
            });
            println!("{}", int_side.report());
            let s = f32_side.p50 / int_side.p50.max(1e-12);
            println!("  → integer domain is {s:.2}× the f32 panel (W{bits}, batch {batch})");
            int_rows.push(Json::object(vec![
                ("bits", Json::from_f64(bits as f64)),
                ("batch", Json::from_f64(batch as f64)),
                ("f32_panel", ms(&f32_side)),
                ("integer", ms(&int_side)),
                ("speedup_int_vs_f32", Json::from_f64(s)),
            ]));
        }
    }

    // ---- batch-1 gemv decode path at 1024², W4/W8 ----
    println!("== gemv decode path (batch 1, {DIM}×{DIM}) ==");
    let mut gemv_rows: Vec<Json> = Vec::new();
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        let x = Tensor::from_f32(
            (0..DIM).map(|_| rng.next_normal()).collect(),
            &[1, DIM],
        )
        .expect("row");
        let gemv = bench(&format!("fused_gemv_w{bits}_b1"), budget, 20_000, || {
            let _ = kernels::gemm_fused(&x, &m, workers).expect("gemv");
        });
        println!("{}", gemv.report());
        let per_s = 1.0 / gemv.p50.max(1e-12);
        println!("  → {per_s:.0} batch-1 projections/s at W{bits}");
        gemv_rows.push(Json::object(vec![
            ("bits", Json::from_f64(bits as f64)),
            ("gemv", ms(&gemv)),
            ("projections_per_s", Json::from_f64(per_s)),
        ]));
    }

    // ---- in-register weight decode vs the scalar word walk ----
    println!("== in-register unpack vs scalar word walk (whole {DIM}×{DIM} matrix) ==");
    let mut unpack_rows: Vec<Json> = Vec::new();
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        let (qmin, k) = (m.qmin(), DIM);
        let mut out_f = vec![0.0f32; k];
        let scalar_u = bench(&format!("unpack_scalar_w{bits}"), budget, 2_000, || {
            for r in 0..DIM {
                simd::unpack_codes_f32(Isa::Scalar, m.row_words(r), k, bits, qmin, &mut out_f);
            }
        });
        println!("{}", scalar_u.report());
        let simd_u = bench(&format!("unpack_{}_w{bits}", vec_isa.label()), budget, 2_000, || {
            for r in 0..DIM {
                simd::unpack_codes_f32(vec_isa, m.row_words(r), k, bits, qmin, &mut out_f);
            }
        });
        println!("{}", simd_u.report());
        let mut out_w = vec![0i16; k];
        let simd_u16 = bench(&format!("unpack_i16_{}_w{bits}", vec_isa.label()), budget, 2_000, || {
            for r in 0..DIM {
                simd::unpack_codes_i16(vec_isa, m.row_words(r), k, bits, qmin, &mut out_w);
            }
        });
        println!("{}", simd_u16.report());
        let s = scalar_u.p50 / simd_u.p50.max(1e-12);
        println!("  → in-register f32 decode is {s:.2}× the scalar walk (W{bits})");
        unpack_rows.push(Json::object(vec![
            ("bits", Json::from_f64(bits as f64)),
            ("scalar_f32", ms(&scalar_u)),
            ("simd_f32", ms(&simd_u)),
            ("simd_i16", ms(&simd_u16)),
            ("speedup_simd_vs_scalar", Json::from_f64(s)),
        ]));
    }

    // ---- i16-madd route vs i32 route vs f32 panel, W4/W8 × batch {1, 8} ----
    println!("== i16-madd fused route vs i32 route vs f32 panel ({DIM}×{DIM}) ==");
    let d_serial = Dispatch::serial();
    let mut madd_rows: Vec<Json> = Vec::new();
    for bits in [4u32, 8] {
        let m = bench_matrix(bits, 7);
        for batch in [1usize, 8] {
            // non-integral activations keep the f32 row on the panel kernel;
            // the integer rows get A8-shaped codes so both routes are legal
            let xf = Tensor::from_f32(
                (0..batch * DIM).map(|_| rng.next_normal()).collect(),
                &[batch, DIM],
            )
            .expect("f32 activations");
            let xi = Tensor::from_f32(
                (0..batch * DIM).map(|_| rng.below(255) as f32 - 127.0).collect(),
                &[batch, DIM],
            )
            .expect("integer activations");
            let f32_side = bench(&format!("fused_madd_f32_w{bits}_b{batch}"), budget, 2_000, || {
                let _ = kernels::gemm_fused_with(&xf, &m, &d_serial).expect("f32 fused");
            });
            println!("{}", f32_side.report());
            let dot32 = bench(&format!("fused_madd_dot32_w{bits}_b{batch}"), budget, 2_000, || {
                let _ = kernels::gemm_fused_int_route(&xi, &m, &d_serial, kernels::IntRoute::Dot32)
                    .expect("dot32 route");
            });
            println!("{}", dot32.report());
            let madd = bench(&format!("fused_madd_madd_w{bits}_b{batch}"), budget, 2_000, || {
                let _ = kernels::gemm_fused_int_route(&xi, &m, &d_serial, kernels::IntRoute::Madd)
                    .expect("madd route");
            });
            println!("{}", madd.report());
            let s_f32 = f32_side.p50 / madd.p50.max(1e-12);
            let s_dot = dot32.p50 / madd.p50.max(1e-12);
            println!("  → madd is {s_f32:.2}× the f32 panel, {s_dot:.2}× the i32 route (W{bits}A8, batch {batch})");
            madd_rows.push(Json::object(vec![
                ("bits", Json::from_f64(bits as f64)),
                ("batch", Json::from_f64(batch as f64)),
                ("f32_panel", ms(&f32_side)),
                ("int_dot32", ms(&dot32)),
                ("int_madd", ms(&madd)),
                ("speedup_madd_vs_f32", Json::from_f64(s_f32)),
                ("speedup_madd_vs_dot32", Json::from_f64(s_dot)),
            ]));
        }
    }

    // ---- BENCH_kernels.json at the repo root ----
    let doc = Json::object(vec![
        ("bench", Json::from_str_val("kernels")),
        ("workers", Json::from_f64(workers as f64)),
        ("matmul_nt_1024", matmul_json),
        ("matmul_nt_isa", isa_json),
        ("gemv_nt_isa", Json::Arr(gemv_isa_rows)),
        ("fused_1024", Json::Arr(fused_rows)),
        ("fused_int_1024", Json::Arr(int_rows)),
        ("gemv_decode_1024", Json::Arr(gemv_rows)),
        ("unpack_1024", Json::Arr(unpack_rows)),
        ("fused_madd_1024", Json::Arr(madd_rows)),
    ]);
    let out = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_kernels.json");
    match std::fs::write(out, json::to_string(&doc, 2) + "\n") {
        Ok(()) => println!("wrote {out}"),
        Err(e) => eprintln!("could not write {out}: {e}"),
    }
}
