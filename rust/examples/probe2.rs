fn main() -> anyhow::Result<()> {
    let path = std::env::args().nth(1)
        .unwrap_or("artifacts/dec_small_lma.q.l0.flexround.wa.hlo.txt".into());
    eprintln!("parsing {path}");
    let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    eprintln!("parsed ok");
    let comp = xla::XlaComputation::from_proto(&proto);
    eprintln!("proto->comp ok");
    let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("{e:?}"))?;
    let _exe = client.compile(&comp).map_err(|e| anyhow::anyhow!("{e:?}"))?;
    eprintln!("compiled ok");
    Ok(())
}
