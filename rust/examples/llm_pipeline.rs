//! End-to-end LLM driver — the headline claim of the paper (§4.3, Table 7):
//! a language model quantized to 8-bit weights (per-channel) and 8-bit
//! activations (per-tensor) by block-by-block reconstruction stays close to
//! its half-precision baseline on zero-shot reasoning AND perplexity,
//! without any assumption on activation-outlier structure — and FlexRound
//! beats AdaRound throughout.
//!
//! This is the EXPERIMENTS.md "end-to-end validation" run: it loads the
//! pre-trained llm_mini checkpoint, serves the full PTQ pipeline through the
//! PJRT runtime (Python is never invoked), and reports every Table 7 column.
//!
//! ```text
//! cargo run --release --example llm_pipeline
//! ```

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::report::{Reporter, Table};
use flexround::runtime::Pjrt;
use flexround::{eval, Result};
use std::path::Path;
use std::time::Instant;

fn main() -> Result<()> {
    let art = Path::new("artifacts");
    let man = Manifest::load(art)?;
    let rt = Pjrt::new(art)?;
    let sess = Session::open(&rt, &man, "llm_mini")?;
    let rep = Reporter::new(Path::new("reports"), false)?;

    println!(
        "llm_mini: {} transformer layers, per-channel W quant, {} calib sequences",
        sess.model.units.len(),
        sess.model.calib_n
    );

    let mut table = Table::new(
        "Table 7 analog: llm_mini 8/8 zero-shot + causal LM",
        &["Method", "grammar", "copy", "parity", "PPL"],
    );

    // half-precision row
    let t0 = Instant::now();
    let mut row = vec!["Half-precision".to_string()];
    for task in eval::MC_TASKS {
        row.push(format!("{:.2}", 100.0 * eval::eval_mc(&sess, None, task)?));
    }
    row.push(format!("{:.2}", eval::eval_ppl(&sess, None, "eval_x")?));
    table.row(row);
    println!("fp eval in {:.1}s", t0.elapsed().as_secs_f64());

    for method in ["adaround", "flexround"] {
        let mut plan = Plan::new("llm_mini", method);
        plan.mode = "wa".into();
        plan.bits_w = 8;
        plan.abits = 8;
        plan.drop_p = 0.5; // QDrop setting ("Q + X")
        plan.iters = 200;
        plan.verbose = true;
        let t0 = Instant::now();
        let r = sess.quantize(&plan)?;
        println!(
            "{method}: {} recon steps in {:.1}s ({:.1} steps/s)",
            r.recon_steps,
            r.recon_seconds,
            r.recon_steps as f64 / r.recon_seconds.max(1e-9)
        );
        let mut row = vec![format!("Q + {}", if method == "flexround" {
            "FlexRound (Ours)"
        } else {
            "AdaRound"
        })];
        for task in eval::MC_TASKS {
            row.push(format!("{:.2}", 100.0 * eval::eval_mc(&sess, Some(&r), task)?));
        }
        row.push(format!("{:.2}", eval::eval_ppl(&sess, Some(&r), "eval_x")?));
        table.row(row);
        println!("{method} total {:.1}s", t0.elapsed().as_secs_f64());
    }

    rep.table("example_llm_pipeline", &table)?;
    println!("{}", rt.stats.borrow().summary());
    Ok(())
}
