//! Quickstart: quantize one model with FlexRound and compare against
//! rounding-to-nearest and full precision.
//!
//! ```text
//! make artifacts            # once (Python build path)
//! cargo run --release --example quickstart
//! ```

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::runtime::Pjrt;
use flexround::{eval, Result};
use std::path::Path;

fn main() -> Result<()> {
    let art = Path::new("artifacts");
    let man = Manifest::load(art)?;
    let rt = Pjrt::new(art)?;
    println!("PJRT platform: {}", rt.platform());

    let model = "tinymobilenet";
    let sess = Session::open(&rt, &man, model)?;
    println!(
        "model {model}: {} units, trained fp metric {:?}",
        sess.model.units.len(),
        sess.model.fp_metric
    );

    // full-precision baseline (runs the fp unit chain end to end)
    let fp = eval::eval_cnn_fp(&sess)?;
    println!("full-precision        top1/top5 = {:.4}/{:.4}", fp["top1"], fp["top5"]);

    // rounding-to-nearest at 4-bit: no learning, just the init grids
    let mut rtn = Plan::new(model, "rtn");
    rtn.bits_w = 4;
    let r = sess.quantize(&rtn)?;
    let m = eval::eval_cnn(&sess, &r)?;
    println!("RTN        (4-bit W)  top1/top5 = {:.4}/{:.4}", m["top1"], m["top5"]);

    // FlexRound at 4-bit: learn s1, S2, s3, s4 by block-wise reconstruction
    let mut fx = Plan::new(model, "flexround");
    fx.bits_w = 4;
    fx.iters = 300;
    fx.verbose = false;
    let r = sess.quantize(&fx)?;
    println!("reconstruction losses per unit:");
    for u in &r.units {
        println!("  {:<8} {:.6} → {:.6}", u.unit, u.first_loss, u.final_loss);
    }
    let m = eval::eval_cnn(&sess, &r)?;
    println!("FlexRound  (4-bit W)  top1/top5 = {:.4}/{:.4}", m["top1"], m["top5"]);
    println!("runtime: {}", rt.stats.borrow().summary());
    Ok(())
}
