//! LoRA + PTQ pipeline (paper Table 6): quantize a decoder whose LoRA
//! adapters were merged into the base weights at fine-tuning time, then
//! measure BLEU of greedy generations on seen and *unseen* record
//! categories of the data-to-text task.
//!
//! ```text
//! cargo run --release --example lora_generation
//! ```

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::report::{Reporter, Table};
use flexround::runtime::Pjrt;
use flexround::{eval, Result};
use std::path::Path;

fn main() -> Result<()> {
    let art = Path::new("artifacts");
    let man = Manifest::load(art)?;
    let rt = Pjrt::new(art)?;
    let sess = Session::open(&rt, &man, "dec_lora")?;
    let rep = Reporter::new(Path::new("reports"), false)?;

    let mut table = Table::new(
        "Table 6 analog: LoRA-merged decoder on synth-WebNLG (BLEU)",
        &["Method", "Unseen", "Seen"],
    );

    let fp_seen = eval::eval_d2t_bleu(&sess, None, "seen")?;
    let fp_unseen = eval::eval_d2t_bleu(&sess, None, "unseen")?;
    table.row(vec!["Full-precision (LoRA)".into(),
                   format!("{fp_unseen:.2}"), format!("{fp_seen:.2}")]);
    println!("fp BLEU: seen {fp_seen:.2} unseen {fp_unseen:.2}");

    for method in ["adaround", "flexround"] {
        let mut plan = Plan::new("dec_lora", method);
        plan.mode = "wa".into();
        plan.bits_w = 8;
        plan.drop_p = 0.5;
        plan.iters = 200;
        let r = sess.quantize(&plan)?;
        let seen = eval::eval_d2t_bleu(&sess, Some(&r), "seen")?;
        let unseen = eval::eval_d2t_bleu(&sess, Some(&r), "unseen")?;
        table.row(vec![format!("Q + {method}"), format!("{unseen:.2}"), format!("{seen:.2}")]);
        println!("{method}: BLEU seen {seen:.2} unseen {unseen:.2}");
    }

    rep.table("example_lora_generation", &table)?;
    Ok(())
}
