//! Vision pipeline: a Table 2/3-style mini-study on one CNN — weight-only
//! at several bit-widths, then W/A with the BRECQ and QDrop settings, then
//! the Figure 3 grid-shift analysis of the first block.
//!
//! ```text
//! cargo run --release --example vision_pipeline [model]
//! ```

use flexround::coordinator::{Plan, Session};
use flexround::manifest::Manifest;
use flexround::report::{Reporter, Table};
use flexround::runtime::Pjrt;
use flexround::{eval, quant, Result};
use std::path::Path;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "tinyresnet_a".to_string());
    let art = Path::new("artifacts");
    let man = Manifest::load(art)?;
    let rt = Pjrt::new(art)?;
    let sess = Session::open(&rt, &man, &model)?;
    let rep = Reporter::new(Path::new("reports"), false)?;

    let mut table = Table::new(
        &format!("vision pipeline: {model}"),
        &["Method", "# Bits (W/A)", "Setting", "Top-1", "Top-5"],
    );
    let fp = eval::eval_cnn_fp(&sess)?;
    table.row(vec!["Full-precision".into(), "32/32".into(), "-".into(),
                   format!("{:.4}", fp["top1"]), format!("{:.4}", fp["top5"])]);

    // weight-only at 4/3/2 bits
    for bits in [4u32, 3, 2] {
        for method in ["adaround", "flexround"] {
            let mut plan = Plan::new(&model, method);
            plan.bits_w = bits;
            plan.iters = 250;
            let r = sess.quantize(&plan)?;
            let m = eval::eval_cnn(&sess, &r)?;
            table.row(vec![method.into(), format!("{bits}/32"), "B".into(),
                           format!("{:.4}", m["top1"]), format!("{:.4}", m["top5"])]);
            println!("W{bits} {method}: top1 {:.4}", m["top1"]);
        }
    }

    // W/A 4/4 under both settings
    for setting in ["B", "Q"] {
        for method in ["adaround", "flexround"] {
            let mut plan = Plan::new(&model, method);
            plan.mode = "wa".into();
            plan.bits_w = 4;
            plan.abits = 4;
            plan.iters = 250;
            plan.drop_p = if setting == "Q" { 0.5 } else { 0.0 };
            let r = sess.quantize(&plan)?;
            let m = eval::eval_cnn(&sess, &r)?;
            table.row(vec![method.into(), "4/4".into(), setting.into(),
                           format!("{:.4}", m["top1"]), format!("{:.4}", m["top5"])]);
            println!("W4A4 {setting}+{method}: top1 {:.4}", m["top1"]);
        }
    }
    rep.table(&format!("example_vision_{model}"), &table)?;

    // Figure 3-style analysis on the first quantized block
    let mut plan = Plan::new(&model, "flexround");
    plan.bits_w = 4;
    plan.iters = 250;
    let r = sess.quantize(&plan)?;
    let unit = &sess.model.units[1];
    let st = &r.units[1];
    for gs in quant::grid_shifts(&sess, unit, st)? {
        println!(
            "grid shifts {}/{}: {:.2}% shifted, {:.2}% aggressive (|Δ|≥2), max {}",
            unit.name, gs.layer, 100.0 * gs.shifted_frac, 100.0 * gs.aggressive_frac,
            gs.max_shift
        );
    }
    println!(
        "large-|W| fraction of {model}: {:.3}%",
        100.0 * quant::large_weight_fraction(&sess)
    );
    Ok(())
}
