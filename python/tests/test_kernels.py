"""L1 correctness: every Pallas kernel against the pure-jnp oracle, swept
over shapes/bit-widths/schemes with hypothesis; custom-VJP gradients against
finite differences and the closed forms of Proposition 3.1."""
import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings

from compile import quant as Q
from compile.kernels import baselines as kb
from compile.kernels import flexround as kf
from compile.kernels import ref

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True)
hypothesis.settings.load_profile("ci")


def _w(seed, r, c, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=(r, c)) * scale).astype(np.float32))


def _flex_params(seed, r, c, jitter=True):
    rng = np.random.default_rng(seed + 1)
    def pos(shape):
        if jitter:
            return jnp.asarray((0.5 + rng.random(shape)).astype(np.float32))
        return jnp.ones(shape, jnp.float32)
    return pos((r, 1)), pos((r, c)), pos((r, 1)), pos((1, c))


shape_st = st.tuples(st.integers(1, 40), st.integers(1, 50))
bits_st = st.integers(2, 8)


@given(shape_st, bits_st, st.booleans(), st.integers(0, 5))
def test_flexround_fwd_matches_ref(shape, bits, symmetric, seed):
    r, c = shape
    w = _w(seed, r, c, scale=1.5)
    s1, s2, s3, s4 = _flex_params(seed, r, c)
    qmin, qmax = ref.qrange(bits, symmetric)
    s1v, zpv = ref.minmax_scale(w, bits, symmetric)
    s1 = jnp.broadcast_to(jnp.reshape(s1v, (1, 1)), (r, 1))
    zp = jnp.broadcast_to(jnp.reshape(zpv, (1, 1)), (r, 1))
    out = kf.flexround_fq(w, s1, s2, s3, s4, zp, float(qmin), float(qmax))
    exp = ref.flexround(w, s1, s2, s3, s4, qmin, qmax, zp)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)
    codes = kf.flexround_fq_int(w, s1, s2, s3, s4, zp, float(qmin), float(qmax))
    exp_codes = ref.flexround_int(w, s1, s2, s3, s4, qmin, qmax, zp)
    np.testing.assert_allclose(codes, exp_codes, atol=0)


@given(shape_st, st.integers(0, 5))
def test_flexround_with_unit_scales_is_rtn(shape, seed):
    r, c = shape
    w = _w(seed, r, c)
    qmin, qmax = ref.qrange(4, True)
    s1v, _ = ref.minmax_scale(w, 4, True)
    s1 = jnp.full((r, 1), s1v)
    ones_rc = jnp.ones((r, c), jnp.float32)
    ones_r = jnp.ones((r, 1), jnp.float32)
    ones_c = jnp.ones((1, c), jnp.float32)
    zp = jnp.zeros((r, 1), jnp.float32)
    out = kf.flexround_fq(w, s1, ones_rc, ones_r, ones_c, zp, float(qmin), float(qmax))
    exp = ref.rtn(w, s1, qmin, qmax)
    np.testing.assert_allclose(out, exp, rtol=1e-6, atol=1e-6)


@given(shape_st, bits_st, st.integers(0, 4))
def test_rtn_adaround_adaquant_match_ref(shape, bits, seed):
    r, c = shape
    w = _w(seed, r, c)
    qmin, qmax = ref.qrange(bits, False)
    s1v, zpv = ref.minmax_scale(w, bits, False)
    s1 = jnp.full((r, 1), float(s1v))
    zp = jnp.full((r, 1), float(zpv))
    np.testing.assert_allclose(
        kb.rtn(w, s1, zp, float(qmin), float(qmax)),
        ref.rtn(w, s1, qmin, qmax, zp), rtol=1e-6, atol=1e-6)
    v = ref.adaround_init_v(w, s1)
    np.testing.assert_allclose(
        kb.adaround(w, s1, v, zp, float(qmin), float(qmax)),
        ref.adaround(w, s1, v, qmin, qmax, zp), rtol=1e-5, atol=1e-5)
    vq = _w(seed + 7, r, c, scale=0.01)
    np.testing.assert_allclose(
        kb.adaquant(w, s1, vq, zp, float(qmin), float(qmax)),
        ref.adaquant(w, s1, vq, qmin, qmax, zp), rtol=1e-6, atol=1e-6)


@given(st.tuples(st.integers(1, 60), st.integers(1, 30)), st.integers(0, 4))
def test_lsq_act_matches_ref(shape, seed):
    n, d = shape
    x = _w(seed, n, d, scale=2.0)
    step = jnp.full((1, 1), 0.07)
    zp = jnp.full((1, 1), 3.0)
    qmin, qmax = ref.qrange(8, False)
    np.testing.assert_allclose(
        kb.lsq_act(x, step, zp, float(qmin), float(qmax)),
        ref.lsq_act(x, step.reshape(()), qmin, qmax, zp.reshape(())),
        rtol=1e-6, atol=1e-6)


@given(st.tuples(st.integers(2, 24), st.integers(2, 24)),
       st.tuples(st.integers(1, 16), st.integers(2, 8)), st.integers(0, 3))
def test_fused_matmul_matches_unfused(shape, bdims, seed):
    r, c = shape
    b, bits = bdims
    w = _w(seed, r, c)
    x = _w(seed + 3, b, c)
    s1, s2, s3, s4 = _flex_params(seed, r, c)
    zp = jnp.zeros((r, 1), jnp.float32)
    qmin, qmax = ref.qrange(bits, True)
    out = kf.flexround_matmul(x, w, s1, s2, s3, s4, zp, float(qmin), float(qmax))
    exp = ref.flexround_matmul(w, s1, s2, s3, s4, qmin, qmax, zp, x)
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Gradients
# ---------------------------------------------------------------------------

def test_flexround_grad_matches_prop31_closed_form():
    """∂L/∂S2 must equal −W/S'²·s1·mask·∂L/∂Ŵ — Proposition 3.1."""
    r, c = 6, 9
    w = _w(11, r, c)
    s1, s2, s3, s4 = _flex_params(11, r, c)
    zp = jnp.zeros((r, 1), jnp.float32)
    qmin, qmax = -8.0, 7.0
    g = _w(12, r, c)

    def loss(s1_, s2_, s3_, s4_):
        out = Q.fq_flexround(w, s1_, s2_, s3_, s4_, zp, jnp.float32(qmin), jnp.float32(qmax))
        return jnp.sum(out * g)

    ds1, ds2, ds3, ds4 = jax.grad(loss, argnums=(0, 1, 2, 3))(s1, s2, s3, s4)
    es1, es2, es3, es4 = ref.flexround_bwd(w, s1, s2, s3, s4, qmin, qmax, 0.0, g)
    np.testing.assert_allclose(ds1, es1.reshape(r, 1) if es1.ndim == 2 else es1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ds2, es2, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ds3, es3, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ds4, es4, rtol=1e-5, atol=1e-5)
    # Prop 3.1: dS2 ∝ −W (elementwise, for in-range weights)
    div = s1 * s2 * s3 * s4
    n = jnp.round(w / div)
    inside = (n >= qmin) & (n <= qmax)
    expected_sign = -jnp.sign(w) * jnp.sign(g)
    actual_sign = jnp.sign(ds2)
    mask = inside & (jnp.abs(w) > 1e-3) & (jnp.abs(g) > 1e-3)
    assert bool(jnp.all(jnp.where(mask, actual_sign == expected_sign, True)))


def test_flexround_grad_matches_finite_difference_smoothed():
    """STE grads track finite differences of the *unrounded* surrogate."""
    r, c = 4, 5
    w = _w(21, r, c)
    s1, s2, s3, s4 = _flex_params(21, r, c)
    zp = jnp.zeros((r, 1), jnp.float32)
    g = jnp.ones((r, c), jnp.float32)

    # smooth surrogate: replace round() by identity — STE's model of the op
    def smooth(s2_):
        div = s1 * s2_ * s3 * s4
        return jnp.sum(s1 * jnp.clip(w / div, -8.0, 7.0) * g)

    def hard(s2_):
        return jnp.sum(
            Q.fq_flexround(w, s1, s2_, s3, s4, zp, jnp.float32(-8), jnp.float32(7)) * g)

    gs = jax.grad(smooth)(s2)
    gh = jax.grad(hard)(s2)
    np.testing.assert_allclose(gh, gs, rtol=1e-4, atol=1e-4)


def test_adaround_grad_zero_at_saturated_h():
    r, c = 3, 4
    w = _w(31, r, c)
    s1 = jnp.full((r, 1), 0.1)
    zp = jnp.zeros((r, 1), jnp.float32)
    v = jnp.full((r, c), 30.0)  # h(V) saturated at 1 → zero gradient

    def loss(v_):
        return jnp.sum(Q.fq_adaround(w, s1, v_, zp, jnp.float32(-8), jnp.float32(7)))

    g = jax.grad(loss)(v)
    np.testing.assert_allclose(g, jnp.zeros_like(g), atol=1e-7)


def test_adaquant_grads():
    r, c = 5, 7
    w = _w(41, r, c)
    s1 = jnp.full((r, 1), 0.09)
    zp = jnp.zeros((r, 1), jnp.float32)
    v = _w(42, r, c, scale=0.01)
    gcot = _w(43, r, c)

    def loss(s1_, v_):
        return jnp.sum(Q.fq_adaquant(w, s1_, v_, zp, jnp.float32(-8), jnp.float32(7)) * gcot)

    ds1, dv = jax.grad(loss, argnums=(0, 1))(s1, v)
    es1, ev = ref.adaquant_bwd(w, s1, v, -8.0, 7.0, 0.0, gcot)
    np.testing.assert_allclose(ds1, es1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(dv, ev, rtol=1e-5, atol=1e-5)


def test_lsq_grad_scale_applied():
    n, d = 8, 6
    x = _w(51, n, d)
    step = jnp.full((1, 1), 0.05)
    zp = jnp.zeros((1, 1), jnp.float32)

    def loss(step_):
        return jnp.sum(Q.fq_lsq_act(x, step_, zp, jnp.float32(0), jnp.float32(255)))

    ds = jax.grad(loss)(step)
    _, es = ref.lsq_act_bwd(x, step.reshape(()), 0.0, 255.0, 0.0, jnp.ones_like(x))
    np.testing.assert_allclose(ds.reshape(()), es, rtol=1e-5, atol=1e-6)


def test_positivity_clamp():
    p = {"s1": jnp.asarray([[-1.0]]), "s2": jnp.asarray([[0.5, -2.0]])}
    out = Q.clamp_positive(p)
    assert float(out["s1"][0, 0]) == pytest.approx(1e-6)
    assert float(out["s2"][0, 1]) == pytest.approx(1e-6)


def test_conv_2d_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(3, 3, 4, 8)).astype(np.float32))
    w2d = Q.conv_to_2d(w)
    assert w2d.shape == (8, 36)
    back = Q.conv_from_2d(w2d, (3, 3, 4, 8))
    np.testing.assert_allclose(back, w, atol=0)


def test_vmem_estimate_within_budget():
    # any block of the default tiling must fit a 16 MiB VMEM core
    assert kf.vmem_bytes_estimate(4096, 4096, batch=512) < 16 * 1024 * 1024
