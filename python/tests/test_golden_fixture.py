"""The checked-in golden fixture must be exactly what the generator emits
(determinism + no hand edits), and internally consistent with the ref.py
formulas.  Pure stdlib — runs in images without JAX."""
import json
import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))
REPO = os.path.normpath(os.path.join(HERE, "..", ".."))
FIXTURE = os.path.join(REPO, "rust", "tests", "fixtures", "flexround_golden.json")


def test_fixture_matches_generator(tmp_path):
    with open(FIXTURE) as f:
        committed = f.read()
    env = dict(os.environ)
    subprocess.run(
        [sys.executable, os.path.join(HERE, "gen_flexround_golden.py")],
        check=True, env=env, cwd=str(tmp_path),
    )
    with open(FIXTURE) as f:
        regenerated = f.read()
    assert committed == regenerated, "fixture drifted from its generator"


def test_fixture_internal_consistency():
    with open(FIXTURE) as f:
        doc = json.load(f)
    assert doc["cases"], "fixture has no cases"
    for case in doc["cases"]:
        r, c = case["rows"], case["cols"]
        qmin, qmax = case["qmin"], case["qmax"]
        assert len(case["w"]) == r * c == len(case["what"]) == len(case["codes"])
        for i in range(r):
            for j in range(c):
                k = i * c + j
                n = case["codes"][k]
                assert qmin <= n <= qmax and n == int(n)
                # Ŵ = s1 · (n − z) must hold exactly as written
                expect = case["s1"][i] * (n - case["zp"][i])
                assert abs(case["what"][k] - expect) < 1e-9
