"""Generate golden fixtures for the native Rust FlexRound backend.

Writes ``rust/tests/fixtures/flexround_golden.json`` — small (W, s1, S2, s3,
s4, zp) instances together with the expected fake-quantized weights Ŵ,
integer codes, and fused-matmul outputs Ŷ = X·Ŵᵀ.

The expected values are computed here in pure-Python double precision using
*exactly* the formulas of ``python/compile/kernels/ref.py`` (Eq. 2 of the
paper, banker's rounding like ``jnp.round``); the pytest suite pins the
Pallas kernels against ``ref.py``, so agreement with this file is (by
transitivity) agreement with the reference kernels — and this script needs
no JAX, so the fixture can be regenerated in any environment:

    python3 python/tests/gen_flexround_golden.py

Weights are nudged away from rounding-boundary halves (|frac − 0.5| > 1e-3)
so the f32 arithmetic on the Rust side cannot round differently.
"""
from __future__ import annotations

import json
import os


# -- tiny deterministic PRNG (no numpy in the minimal image) ----------------

class Lcg:
    def __init__(self, seed: int):
        self.s = seed & 0xFFFFFFFFFFFFFFFF

    def next_u32(self) -> int:
        self.s = (self.s * 6364136223846793005 + 1442695040888963407) & 0xFFFFFFFFFFFFFFFF
        return (self.s >> 33) & 0xFFFFFFFF

    def uniform(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * (self.next_u32() / 2**32)

    def normal(self) -> float:
        # Irwin–Hall(12) approximation — plenty for fixture data.
        return sum(self.uniform(0.0, 1.0) for _ in range(12)) - 6.0


# -- ref.py math in pure python ---------------------------------------------

def round_half_even(x: float) -> float:
    f = float(int(x // 1))  # floor
    d = x - f
    if d == 0.5:
        return f if (f % 2.0) == 0.0 else f + 1.0
    return float(round(x))  # python round() is banker's, matches jnp.round


def flexround(w, r, c, s1, s2, s3, s4, zp, qmin, qmax):
    what, codes = [], []
    for i in range(r):
        for j in range(c):
            k = i * c + j
            div = s1[i] * s2[k] * s3[i] * s4[j]
            n = round_half_even(w[k] / div) + zp[i]
            n_c = min(max(n, qmin), qmax)
            codes.append(n_c)
            what.append(s1[i] * (n_c - zp[i]))
    return what, codes


def matmul_nt(x, b, k, what, r):
    out = []
    for bi in range(b):
        for ri in range(r):
            out.append(sum(x[bi * k + t] * what[ri * k + t] for t in range(k)))
    return out


def nudge_off_boundaries(w, r, c, s1, s2, s3, s4):
    """Shift any weight whose division ratio sits within 1e-3 of a rounding
    half-boundary, so f32/f64 cannot disagree on the rounded integer."""
    for i in range(r):
        for j in range(c):
            k = i * c + j
            div = s1[i] * s2[k] * s3[i] * s4[j]
            for _ in range(100):
                frac = (w[k] / div) % 1.0
                if abs(frac - 0.5) > 1e-3:
                    break
                w[k] += 3e-3 * div
    return w


def make_case(name, rng, r, c, batch, qmin, qmax, symmetric):
    w = [rng.normal() * 0.5 for _ in range(r * c)]
    s2 = [rng.uniform(0.9, 1.1) for _ in range(r * c)]
    s3 = [rng.uniform(0.95, 1.05) for _ in range(r)]
    s4 = [rng.uniform(0.95, 1.05) for _ in range(c)]
    s1, zp = [], []
    for i in range(r):
        row = w[i * c:(i + 1) * c]
        if symmetric:
            amax = max(abs(v) for v in row)
            s1.append(max(amax / qmax, 1e-8))
            zp.append(0.0)
        else:
            wmax, wmin = max(row), min(row)
            s = max((wmax - wmin) / (qmax - qmin), 1e-8)
            s1.append(s)
            zp.append(qmin - round_half_even(wmin / s))
    if name.endswith("clip"):
        # shrink the first row's grid so its extremes saturate (clamp path)
        s1[0] *= 0.25
    w = nudge_off_boundaries(w, r, c, s1, s2, s3, s4)
    what, codes = flexround(w, r, c, s1, s2, s3, s4, zp, qmin, qmax)
    x = [rng.normal() for _ in range(batch * c)]
    y = matmul_nt(x, batch, c, what, r)
    return {
        "name": name, "rows": r, "cols": c, "batch": batch,
        "qmin": qmin, "qmax": qmax,
        "w": w, "s1": s1, "s2": s2, "s3": s3, "s4": s4, "zp": zp,
        "what": what, "codes": codes, "x": x, "y": y,
    }


def main():
    rng = Lcg(0x5EED_F00D)
    cases = [
        make_case("per_row_sym_4bit", rng, 4, 6, 3, -8.0, 7.0, True),
        make_case("per_row_sym_3bit", rng, 5, 4, 4, -4.0, 3.0, True),
        make_case("asym_8bit_clip", rng, 3, 5, 4, 0.0, 255.0, False),
    ]
    out = os.path.join(os.path.dirname(__file__), "..", "..",
                       "rust", "tests", "fixtures", "flexround_golden.json")
    out = os.path.normpath(out)
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        json.dump({"cases": cases}, f, indent=1)
        f.write("\n")
    print(f"wrote {out} ({len(cases)} cases)")


if __name__ == "__main__":
    main()
