"""L2 correctness: reconstruction graphs reduce loss, packs round-trip,
models have the advertised shapes, CLE/AHB preserve function, data
generators are deterministic, FXT round-trips."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import cle as C
from compile import data as D
from compile import fxt
from compile import graphs as G
from compile import models as M
from compile import quant as Q
from compile.kernels import ref


@pytest.fixture(scope="module")
def mobilenet():
    model = M.tinymobilenet()
    params = M.fold_bn(model, M.init_model(model, 0, init_gain=2.0))
    return model, params


@pytest.fixture(scope="module")
def decoder():
    model = M.dec_small()
    return model, M.init_model(model, 0)


def test_recon_reduces_loss(mobilenet):
    model, params = mobilenet
    unit = model.units[1]
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 12, 12, 8)).astype(np.float32))
    y = G.fp_unit_fwd(model, params, unit)(x)
    views = G.layer_views(model, params, unit)
    pack = G.ParamPack.build("flexround", views, "w", 0, False)
    flat0 = pack.init_values("flexround", views, 4, True, False)
    # initial loss = RTN loss
    qmin, qmax = ref.qrange(4, True)
    fwd = G.quantized_unit_fwd(model, params, unit, "flexround", "w", pack, views)
    y0 = fwd([jnp.asarray(a) for a in flat0], x, float(qmin), float(qmax),
             0.0, 255.0, jnp.float32(0), jax.random.PRNGKey(0))
    loss0 = float(jnp.mean((y0 - y) ** 2))
    final, _, _ = G.python_recon_unit(model, params, unit, "flexround", "w",
                                      x, y, bits_w=4, iters=60, lr=2e-3)
    assert final < loss0 * 0.9, f"loss {loss0} → {final}: not reduced"


@pytest.mark.parametrize("method", ["adaround", "adaquant", "flexround",
                                    "flexround_fixed_s1", "flexround_no_s34",
                                    "adaquant_flexround"])
def test_all_methods_run_and_reduce(mobilenet, method):
    model, params = mobilenet
    unit = model.units[1]
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 12, 12, 8)).astype(np.float32))
    y = G.fp_unit_fwd(model, params, unit)(x)
    lr = 1e-2 if method == "adaround" else 2e-3
    final, flat, pack = G.python_recon_unit(model, params, unit, method, "w",
                                            x, y, bits_w=4, iters=25, lr=lr)
    assert np.isfinite(final)
    # positivity invariant on the divisive scales
    for e, p in zip(pack.entries, flat):
        key = e.name.split(".")[1]
        if key in ("s1", "s2", "s3", "s4"):
            assert float(jnp.min(p)) > 0.0, f"{e.name} went non-positive"


def test_wa_mode_learns_act_steps(decoder):
    model, params = decoder
    unit = model.units[0]
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(8, D.LM_SEQ, 48)).astype(np.float32))
    y = G.fp_unit_fwd(model, params, unit)(x)
    final, flat, pack = G.python_recon_unit(
        model, params, unit, "flexround", "wa", x, y, bits_w=8, iters=20,
        lr=2e-3, symmetric=False, drop_p=0.5, seed=3)
    assert np.isfinite(final)
    # act steps present and positive
    act_entries = [i for i, e in enumerate(pack.entries) if e.name.startswith("act")]
    assert len(act_entries) == 12  # 6 sites × (step, zp)
    for i in act_entries:
        assert float(jnp.min(flat[i])) > 0 or pack.entries[i].name.endswith("zp")


def test_pack_roundtrip(decoder):
    model, params = decoder
    unit = model.units[0]
    views = G.layer_views(model, params, unit)
    pack = G.ParamPack.build("flexround", views, "wa", G.n_act_sites(unit), False)
    flat = [jnp.full(e.shape, float(i + 1)) for i, e in enumerate(pack.entries)]
    per_layer, acts = pack.unflatten(flat)
    assert len(per_layer) == 6          # wq wk wv wo fc1 fc2
    assert set(per_layer[0].keys()) == {"s1", "zp", "s2", "s3", "s4"}
    assert len(acts) == 6
    # w-mode pack is a strict prefix of wa-mode pack
    pack_w = G.ParamPack.build("flexround", views, "w", 0, False)
    assert [e.name for e in pack_w.entries] == [
        e.name for e in pack.entries[: len(pack_w.entries)]]


def test_per_channel_pack_shapes():
    model = M.llm_mini()
    params = M.init_model(model, 0)
    unit = model.units[0]
    views = G.layer_views(model, params, unit)
    pack = G.ParamPack.build("flexround", views, "w", 0, per_channel=True)
    by_name = {e.name: e.shape for e in pack.entries}
    assert by_name["wq.s1"] == (128, 1)
    assert by_name["wq.zp"] == (128, 1)
    pack_pt = G.ParamPack.build("flexround", views, "w", 0, per_channel=False)
    assert {e.name: e.shape for e in pack_pt.entries}["wq.s1"] == (1, 1)


# ---------------------------------------------------------------------------
# Models
# ---------------------------------------------------------------------------

def test_model_shapes_all():
    for name, build in M.MODEL_BUILDERS.items():
        model = build()
        params = M.init_model(model, 0)
        if model.kind == "cnn":
            x = jnp.zeros((2, D.IMG_SIZE, D.IMG_SIZE, 3), jnp.float32)
            logits, _ = M.forward_train(model, params, x, train=False)
            assert logits.shape == (2, D.IMG_CLASSES), name
        elif model.kind == "decoder":
            seq, vocab = model.meta["seq"], model.meta["vocab"]
            toks = jnp.zeros((2, seq), jnp.int32)
            logits, _ = M.forward_train(model, params, toks, train=False)
            assert logits.shape == (2, seq, vocab), name
        else:
            toks = jnp.zeros((2, D.NLU_SEQ), jnp.int32)
            out, _ = M.forward_train(model, params, toks, train=False, task="entail")
            assert out.shape == (2, 2), name
            s, e = M.forward_train(model, params, toks, train=False, task="span")[0]
            assert s.shape == (2, D.NLU_SEQ), name


def test_bn_fold_preserves_eval_forward():
    model = M.tinyresnet_a()
    params = M.init_model(model, 3)
    # give BN non-trivial stats
    for u in model.units:
        if u.kind == "head_fc":
            continue
        for l in u.layers:
            bn = params["units"][u.name]["bn"][l.name]
            rng = np.random.default_rng(hash(l.name) % 1000)
            bn["mean"] = jnp.asarray(rng.normal(size=bn["mean"].shape).astype(np.float32) * 0.1)
            bn["var"] = jnp.asarray((0.5 + rng.random(bn["var"].shape)).astype(np.float32))
            bn["g"] = jnp.asarray((0.8 + 0.4 * rng.random(bn["g"].shape)).astype(np.float32))
    x = jnp.asarray(np.random.default_rng(5).normal(
        size=(4, D.IMG_SIZE, D.IMG_SIZE, 3)).astype(np.float32))
    y_bn, _ = M.forward_train(model, params, x, train=False)
    folded = M.fold_bn(model, params)
    # run through the QModel topology (no BN)
    h = x
    for u in model.units:
        if u.kind == "head_fc":
            continue
        views = G.layer_views(model, folded, u)
        ws = [G.w2d_to_native(v, v.w2d) for v in views]
        bs = [v.bias for v in views]
        h = M.apply_unit(u, ws, bs, None, h)
    logits = M.linear(h.mean(axis=(1, 2)), folded["head"]["fc_w"], folded["head"]["fc_b"])
    np.testing.assert_allclose(logits, y_bn, rtol=1e-3, atol=1e-4)


def test_lora_merge_equals_adapter_forward():
    model = M.dec_lora()
    params = M.init_model(model, 0)
    adapters = M.lora_init(model, 1)
    # randomize B so the adapter is non-zero
    for k in adapters:
        adapters[k]["b"] = jnp.asarray(
            np.random.default_rng(2).normal(size=adapters[k]["b"].shape).astype(np.float32) * 0.1)
    toks = jnp.asarray(np.random.default_rng(3).integers(
        1, D.D2T_VOCAB, size=(2, D.D2T_SEQ)).astype(np.int32))
    y_adapter = M.forward_lora(model, params, adapters, toks)
    merged = M.lora_merge(model, params, adapters)
    y_merged, _ = M.forward_train(model, merged, toks, train=False)
    np.testing.assert_allclose(y_adapter, y_merged, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# CLE / AHB
# ---------------------------------------------------------------------------

def test_cle_preserves_function_with_relu(mobilenet):
    model_b = M.tinymobilenet()
    params = M.fold_bn(model_b, M.init_model(model_b, 7, init_gain=2.0))
    model_b = C.replace_relu6(model_b)
    x = jnp.asarray(np.random.default_rng(8).normal(
        size=(4, D.IMG_SIZE, D.IMG_SIZE, 3)).astype(np.float32))

    def fwd(p):
        h = x
        for u in model_b.units:
            if u.kind == "head_fc":
                continue
            views = G.layer_views(model_b, p, u)
            ws = [G.w2d_to_native(v, v.w2d) for v in views]
            bs = [v.bias for v in views]
            h = M.apply_unit(u, ws, bs, None, h)
        return h

    y0 = fwd(params)
    y1 = fwd(C.apply_cle(model_b, params))
    np.testing.assert_allclose(y1, y0, rtol=2e-3, atol=2e-3)


def test_cle_narrows_range_ratio(mobilenet):
    model_b = M.tinymobilenet()
    params = M.fold_bn(model_b, M.init_model(model_b, 9, init_gain=3.0))
    model_b = C.replace_relu6(model_b)

    def ratio(p):
        u = model_b.units[1]
        w1 = p["units"][u.name]["layers"]["expand"]["w"]
        w2 = p["units"][u.name]["layers"]["dw"]["w"]
        r1 = jnp.max(jnp.abs(w1), axis=(0, 1, 2))
        r2 = jnp.max(jnp.abs(w2), axis=(0, 1, 2))
        return float(jnp.mean(jnp.abs(jnp.log(r1 / r2))))

    before = ratio(params)
    after = ratio(C.apply_cle(model_b, params))
    # iterated pairwise CLE over a 3-layer chain doesn't reach the exact
    # fixed point in 2 sweeps, but it must strictly equalize the pair ranges
    assert after < before * 0.6, f"CLE should narrow range ratios: {before} → {after}"


# ---------------------------------------------------------------------------
# Data + FXT
# ---------------------------------------------------------------------------

def test_data_deterministic():
    a1, y1 = D.gen_images(1, 16)
    a2, y2 = D.gen_images(1, 16)
    np.testing.assert_array_equal(a1, a2)
    np.testing.assert_array_equal(y1, y2)
    t1, e1 = D.gen_corpus("lm-a", 8)
    t2, e2 = D.gen_corpus("lm-a", 8)
    np.testing.assert_array_equal(t1, t2)
    assert e1 == e2


def test_nlu_tasks_learnable_labels():
    for task in D.NLU_TASKS:
        toks, ys, nc = D.gen_nlu(task, 5, 400)
        assert toks.shape == (400, D.NLU_SEQ)
        assert nc == 2
        frac = ys.mean()
        assert 0.3 < frac < 0.7, f"{task} label balance {frac}"


def test_mc_answer_distribution():
    ch, ans = D.gen_mc("copy", 3, 64)
    assert ch.shape == (64, D.MC_CHOICES, D.LM_SEQ)
    assert set(np.unique(ans)).issubset({0, 1, 2, 3})


def test_span_answers_in_context():
    toks, s, e = D.gen_span(1, 64)
    assert np.all(e == s + 1)
    assert np.all(s >= 1)
    assert np.all(e < D.NLU_SEQ)


def test_fxt_roundtrip():
    tensors = {
        "a/w": np.arange(12, dtype=np.float32).reshape(3, 4),
        "b": np.asarray([-1, 2, 7], np.int32),
        "scalar": np.float32(3.25).reshape(()),
    }
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.fxt")
        fxt.write(path, tensors)
        back = fxt.read(path)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_beta_schedule_matches_rust_contract():
    # fixed points the Rust side asserts too
    assert G._beta(1, 100) == 20.0
    assert G._beta(100, 100) < 2.5
