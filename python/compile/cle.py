"""Cross-layer equalization (CLE) and absorbing-high-biases (AHB)
preprocessing — Nagel et al. (2019), used by the paper's Table 10 ablation.

CLE rescales channel i shared between two consecutive layers so their
per-channel weight ranges match:  s_i = √(r1_i / r2_i); layer-1 output
channel i is divided by s_i (bias too), layer-2 input channel i multiplied
by s_i.  With a *positively homogeneous* activation between them (ReLU, not
ReLU6) the network function is preserved exactly — which is why the paper
replaces every ReLU6 by ReLU before applying CLE to MobileNetV2.

AHB then absorbs large biases of layer 1 into layer 2's bias:
c_i = max(0, b_i − 3σ_i)  (σ from the folded BN; we use |b| directly since
BN is already folded) — b1_i −= c_i,  b2 += W2[:, i] · c_i.

These run at AOT time (they rewrite the pre-trained weights before the
quantization graphs bake them); the Rust suite re-verifies the invariants
on the exported tensors.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import models as M


def _pairs(unit: M.QUnit) -> List[Tuple[str, str]]:
    """Consecutive (producer, consumer) layer pairs within a unit that share
    a channel dimension through an activation."""
    k = unit.kind
    if k == "invres_block":
        return [("expand", "dw"), ("dw", "project")]
    if k == "res_block":
        return [("conv1", "conv2")]
    if k == "bottleneck_block":
        return [("conv1", "conv2"), ("conv2", "conv3")]
    return []


def _range_out(w, dw: bool):
    """Per-output-channel |w| range.  HWIO layout → out axis = 3."""
    return jnp.max(jnp.abs(w), axis=(0, 1, 2))


def _range_in(w, dw: bool):
    """Per-input-channel |w| range.  Depthwise convs consume channel i via
    their *output* axis (I dimension is 1), so the in-range is over axis 3."""
    if dw:
        return jnp.max(jnp.abs(w), axis=(0, 1, 2))
    return jnp.max(jnp.abs(w), axis=(0, 1, 3))


def equalize_pair(w1, b1, w2, dw1: bool, dw2: bool):
    """One CLE step.  Returns (w1', b1', w2', s)."""
    r1 = _range_out(w1, dw1)
    r2 = _range_in(w2, dw2)
    s = jnp.sqrt(jnp.maximum(r1, 1e-8) / jnp.maximum(r2, 1e-8))
    s = jnp.clip(s, 1e-4, 1e4)
    w1p = w1 / s[None, None, None, :]
    b1p = b1 / s
    if dw2:
        w2p = w2 * s[None, None, None, :]
    else:
        w2p = w2 * s[None, None, :, None]
    return w1p, b1p, w2p, s


def replace_relu6(model: M.QModel) -> M.QModel:
    """ReLU6 → ReLU in-place on the spec (the paper's precondition for CLE)."""
    for u in model.units:
        for l in u.layers:
            l.relu6 = False
    return model


def apply_cle(model: M.QModel, params, iters: int = 2):
    """Iterated pairwise equalization over every unit's chains."""
    out = jax.tree_util.tree_map(lambda a: a, params)
    for _ in range(iters):
        for u in model.units:
            for a, b in _pairs(u):
                la = next(l for l in u.layers if l.name == a)
                lb = next(l for l in u.layers if l.name == b)
                pa = out["units"][u.name]["layers"][a]
                pb = out["units"][u.name]["layers"][b]
                w1, b1, w2, _ = equalize_pair(
                    pa["w"], pa["b"], pb["w"],
                    la.kind == "dwconv", lb.kind == "dwconv")
                pa["w"], pa["b"] = w1, b1
                pb["w"] = w2
    return out


def apply_ahb(model: M.QModel, params, thresh: float = 3.0):
    """Absorb high biases: for each producer/consumer pair, move the part of
    the producer bias above `thresh`·std(b) into the consumer's bias."""
    out = jax.tree_util.tree_map(lambda a: a, params)
    for u in model.units:
        for a, b in _pairs(u):
            lb = next(l for l in u.layers if l.name == b)
            pa = out["units"][u.name]["layers"][a]
            pb = out["units"][u.name]["layers"][b]
            b1 = pa["b"]
            sd = jnp.std(b1) + 1e-8
            c = jnp.maximum(b1 - thresh * sd, 0.0)
            pa["b"] = b1 - c
            w2 = pb["w"]
            if lb.kind == "dwconv":
                # channel-preserving: absorbed constant flows through the
                # center tap of the depthwise kernel
                kh, kw = w2.shape[0], w2.shape[1]
                pb["b"] = pb["b"] + w2[kh // 2, kw // 2, 0, :] * c
            else:
                pb["b"] = pb["b"] + jnp.einsum("hwio,i->o", w2, c) / (
                    w2.shape[0] * w2.shape[1]) * (w2.shape[0] * w2.shape[1])
    return out


def preprocess(model: M.QModel, params):
    """ReLU6→ReLU + CLE + AHB — the full Table 10 preprocessing pipeline."""
    model = replace_relu6(model)
    params = apply_cle(model, params)
    params = apply_ahb(model, params)
    return model, params
