"""Synthetic dataset generators — the offline substitutes for the paper's
ImageNet / GLUE / WikiText2 / PTB / WebNLG / common-sense-reasoning suites.

Every generator is a pure function of an integer seed, so the Python build
path and the Rust test suite can regenerate bit-identical data.  See
DESIGN.md "Substitutions" for the mapping to the paper's datasets and the
argument for why each analog preserves the behaviour PTQ cares about.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# synth-image — ImageNet analog (10-class procedural textures)
# ---------------------------------------------------------------------------

IMG_SIZE = 12
IMG_CLASSES = 10


def gen_images(seed: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gabor-ish textures + colored blobs + noise.

    Returns (x: (n, H, W, 3) f32 in [0,1]-ish standardized, y: (n,) i32).
    Classes differ in orientation/frequency/color so a small CNN separates
    them well above chance but not trivially (noise floor keeps it <100%).
    """
    rng = np.random.default_rng(seed)
    ys = rng.integers(0, IMG_CLASSES, size=n).astype(np.int32)
    xs = np.empty((n, IMG_SIZE, IMG_SIZE, 3), np.float32)
    yy, xx = np.mgrid[0:IMG_SIZE, 0:IMG_SIZE].astype(np.float32) / IMG_SIZE
    for i in range(n):
        c = int(ys[i])
        theta = np.pi * c / IMG_CLASSES
        freq = 2.0 + (c % 5)
        phase = rng.uniform(0, 2 * np.pi)
        grating = np.sin(2 * np.pi * freq * (np.cos(theta) * xx + np.sin(theta) * yy) + phase)
        # class-coloured blob
        cx, cy = rng.uniform(0.2, 0.8, size=2)
        blob = np.exp(-(((xx - cx) ** 2 + (yy - cy) ** 2) / 0.02))
        color = np.array([
            0.5 + 0.5 * np.cos(2 * np.pi * c / IMG_CLASSES),
            0.5 + 0.5 * np.sin(2 * np.pi * c / IMG_CLASSES),
            (c % 3) / 2.0,
        ], np.float32)
        img = 0.5 * grating[..., None] * color + 0.8 * blob[..., None] * color[::-1]
        img += rng.normal(0, 0.55, size=img.shape)
        xs[i] = img.astype(np.float32)
    xs -= xs.mean(axis=(1, 2, 3), keepdims=True)
    xs /= xs.std(axis=(1, 2, 3), keepdims=True) + 1e-6
    return xs, ys


# ---------------------------------------------------------------------------
# synth-lm — WikiText2 / PTB analogs (order-2 Markov grammars)
# ---------------------------------------------------------------------------

LM_VOCAB = 64
LM_SEQ = 32
BOS = 1
PAD = 0


def _markov_tables(seed: int, vocab: int, branch: int, temperature: float):
    """Sparse order-2 transition tables: each (prev2, prev1) context allows
    `branch` successors with Dirichlet weights sharpened by `temperature`."""
    rng = np.random.default_rng(seed)
    succ = rng.integers(2, vocab, size=(vocab, vocab, branch)).astype(np.int32)
    logits = rng.normal(size=(vocab, vocab, branch)) / temperature
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs /= probs.sum(-1, keepdims=True)
    return succ, probs.astype(np.float64)


@dataclass
class LmCorpus:
    name: str
    vocab: int
    seq: int
    entropy_bits: float  # analytic per-token entropy of the grammar


def gen_lm(seed: int, n: int, branch: int = 6, temperature: float = 1.0,
           vocab: int = LM_VOCAB, seq: int = LM_SEQ) -> Tuple[np.ndarray, float]:
    """Sample `n` sequences from the order-2 grammar.  Returns (tokens
    (n, seq) i32 with BOS prefix, analytic entropy rate in nats) — trained
    models converge to PPL ≈ exp(entropy), so perplexity is meaningful."""
    succ, probs = _markov_tables(seed, vocab, branch, temperature)
    rng = np.random.default_rng(seed + 1)
    toks = np.empty((n, seq), np.int32)
    toks[:, 0] = BOS
    prev2 = np.full(n, BOS, np.int32)
    prev1 = rng.integers(2, vocab, size=n).astype(np.int32)
    toks[:, 1] = prev1
    for t in range(2, seq):
        u = rng.random(n)
        p = probs[prev2, prev1]                      # (n, branch)
        idx = (u[:, None] > np.cumsum(p, -1)).sum(-1).clip(0, p.shape[-1] - 1)
        nxt = succ[prev2, prev1, idx]
        toks[:, t] = nxt
        prev2, prev1 = prev1, nxt
    ent = float(-(probs * np.log(probs)).mean(axis=(0, 1)).sum())
    return toks, ent


# corpus-a (WikiText2 analog): broad branch, soft — higher entropy
# corpus-b (PTB analog): narrow branch, sharp — lower entropy
CORPUS_CFG = {
    "lm-a": dict(seed=101, branch=8, temperature=1.2),
    "lm-b": dict(seed=202, branch=4, temperature=0.6),
}


def gen_corpus(name: str, n: int):
    cfg = CORPUS_CFG[name]
    return gen_lm(cfg["seed"], n, branch=cfg["branch"], temperature=cfg["temperature"])


# ---------------------------------------------------------------------------
# synth-nlu — GLUE analogs (3 sequence-classification tasks)
# ---------------------------------------------------------------------------

NLU_VOCAB = 96
NLU_SEQ = 24
SEP = 2
NLU_CONTENT_LO = 8  # tokens ≥ this are "content"; below: control tokens


def gen_nlu(task: str, seed: int, n: int) -> Tuple[np.ndarray, np.ndarray, int]:
    """Three GLUE-shaped tasks over a shared vocabulary.

    entail : [prem SEP hyp]   — label 1 iff every content token of hyp ∈ prem
             (MNLI analog: pair task, asymmetric relation)
    para   : [s1 SEP s2]      — label 1 iff s2 is a permutation of s1 with k
             tokens swapped through a fixed 'synonym' involution (QQP/MRPC)
    accept : [s]              — label 1 iff s respects an even/odd alternation
             grammar (CoLA analog: single-sentence acceptability)

    Returns (tokens (n, NLU_SEQ), labels (n,), num_classes).
    """
    rng = np.random.default_rng(seed)
    toks = np.full((n, NLU_SEQ), PAD, np.int32)
    toks[:, 0] = BOS
    ys = rng.integers(0, 2, size=n).astype(np.int32)
    syn = _synonym_involution(seed)
    for i in range(n):
        if task == "entail":
            plen = rng.integers(6, 10)
            prem = rng.integers(NLU_CONTENT_LO, NLU_VOCAB, size=plen)
            hlen = rng.integers(3, 6)
            if ys[i] == 1:
                hyp = rng.choice(prem, size=hlen, replace=True)
            else:
                hyp = prem[rng.integers(0, plen, size=hlen)].copy()
                # corrupt at least one token to something outside the premise
                bad = rng.integers(0, hlen)
                cand = rng.integers(NLU_CONTENT_LO, NLU_VOCAB)
                while cand in prem:
                    cand = rng.integers(NLU_CONTENT_LO, NLU_VOCAB)
                hyp[bad] = cand
            seqn = np.concatenate([prem, [SEP], hyp])
        elif task == "para":
            slen = rng.integers(5, 9)
            s1 = rng.integers(NLU_CONTENT_LO, NLU_VOCAB, size=slen)
            if ys[i] == 1:
                s2 = rng.permutation(s1)
                k = rng.integers(0, 3)
                pos = rng.choice(slen, size=min(k, slen), replace=False)
                s2[pos] = syn[s2[pos]]
            else:
                s2 = rng.integers(NLU_CONTENT_LO, NLU_VOCAB, size=slen)
            seqn = np.concatenate([s1, [SEP], s2])
        elif task == "accept":
            slen = rng.integers(8, 16)
            if ys[i] == 1:
                # even/odd parity alternation grammar
                s = np.empty(slen, np.int64)
                par = rng.integers(0, 2)
                for t in range(slen):
                    s[t] = rng.integers(NLU_CONTENT_LO // 2, NLU_VOCAB // 2) * 2 + ((t + par) % 2)
                seqn = s
            else:
                seqn = rng.integers(NLU_CONTENT_LO, NLU_VOCAB, size=slen)
        else:
            raise ValueError(task)
        seqn = seqn[: NLU_SEQ - 1]
        toks[i, 1 : 1 + len(seqn)] = seqn
    return toks, ys, 2


NLU_TASKS = ("entail", "para", "accept")
NLU_SEEDS = {"entail": 311, "para": 322, "accept": 333}


# ---------------------------------------------------------------------------
# synth-d2t — WebNLG analog (data-to-text with seen/unseen categories)
# ---------------------------------------------------------------------------

D2T_VOCAB = 64
D2T_SEQ = 32
D2T_NKEYS = 8
D2T_UNSEEN = (6, 7)  # key categories held out of LoRA fine-tuning
KEY_BASE = 4          # keys are tokens [KEY_BASE, KEY_BASE + D2T_NKEYS)
VAL_BASE = KEY_BASE + D2T_NKEYS
SEP_D2T = 3


def _d2t_template(seed: int):
    """Fixed per-key verbalization templates: key k, value v →
    [open_k, f1(v), f2(v)] where f are deterministic token maps."""
    rng = np.random.default_rng(seed)
    openers = rng.integers(VAL_BASE, D2T_VOCAB, size=D2T_NKEYS).astype(np.int32)
    mix = rng.integers(1, 7, size=(D2T_NKEYS, 2)).astype(np.int32)
    return openers, mix


def gen_d2t(seed: int, n: int, categories=None) -> Tuple[np.ndarray, np.ndarray]:
    """Records → text.  Input: [BOS, k1, v1, k2, v2, SEP]; target completion:
    template expansion of each (k, v).  Returns (full sequences (n, D2T_SEQ),
    completion-start indices (n,)).  BLEU is computed over the completion."""
    rng = np.random.default_rng(seed)
    openers, mix = _d2t_template(9000)
    cats = list(categories) if categories is not None else list(range(D2T_NKEYS))
    toks = np.full((n, D2T_SEQ), PAD, np.int32)
    starts = np.empty(n, np.int32)
    for i in range(n):
        nk = min(int(rng.integers(2, 4)), len(cats))
        keys = rng.choice(cats, size=nk, replace=False)
        vals = rng.integers(0, D2T_VOCAB - VAL_BASE, size=nk)
        seqn = [BOS]
        for k, v in zip(keys, vals):
            seqn += [KEY_BASE + int(k), VAL_BASE + int(v)]
        seqn.append(SEP_D2T)
        starts[i] = len(seqn)
        for k, v in zip(keys, vals):
            o = int(openers[k])
            seqn += [o,
                     VAL_BASE + int((v * mix[k, 0]) % (D2T_VOCAB - VAL_BASE)),
                     VAL_BASE + int((v * mix[k, 1] + k) % (D2T_VOCAB - VAL_BASE))]
        seqn = seqn[:D2T_SEQ]
        toks[i, : len(seqn)] = seqn
    return toks, starts


# ---------------------------------------------------------------------------
# synth-mc — common-sense-reasoning analogs (zero-shot multiple choice)
# ---------------------------------------------------------------------------

MC_CHOICES = 4


def gen_mc(task: str, seed: int, n: int, vocab: int = LM_VOCAB,
           seq: int = LM_SEQ) -> Tuple[np.ndarray, np.ndarray]:
    """Multiple-choice tasks scored by length-normalized log-likelihood under
    the pre-trained grammar LM (the LLaMA-analog protocol):

    grammar : 1 continuation drawn from the true grammar, 3 uniform-random
    copy    : prefix contains a marker token pair; the right choice repeats
              the marked token (HellaSwag-ish surface pattern)
    parity  : right choice continues the even/odd token-parity alternation

    Returns (choices (n, MC_CHOICES, seq), answers (n,)).
    """
    rng = np.random.default_rng(seed)
    out = np.empty((n, MC_CHOICES, seq), np.int32)
    ans = rng.integers(0, MC_CHOICES, size=n).astype(np.int32)
    cfg = CORPUS_CFG["lm-a"]
    succ, probs = _markov_tables(cfg["seed"], vocab, cfg["branch"], cfg["temperature"])
    for i in range(n):
        prefix_len = seq // 2
        toks, _ = gen_lm(int(rng.integers(1 << 30)), 1, branch=cfg["branch"],
                         temperature=cfg["temperature"], vocab=vocab, seq=seq)
        base = toks[0]
        for ch in range(MC_CHOICES):
            s = base.copy()
            if ch == ans[i]:
                if task == "copy":
                    s[prefix_len:] = s[prefix_len - 1]
                elif task == "parity":
                    for t in range(prefix_len, seq):
                        s[t] = (s[t - 1] // 2) * 2 + (1 - (s[t - 1] % 2))
                # task == "grammar": the true continuation is already grammatical
            else:
                s[prefix_len:] = rng.integers(2, vocab, size=seq - prefix_len)
            out[i, ch] = s
    return out, ans


MC_TASKS = ("grammar", "copy", "parity")
MC_SEEDS = {"grammar": 811, "copy": 822, "parity": 833}


# ---------------------------------------------------------------------------
# synth-span — SQuAD analog (span extraction)
# ---------------------------------------------------------------------------

def gen_span(seed: int, n: int, vocab: int = NLU_VOCAB,
             seq: int = NLU_SEQ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Context + query → answer span.  The query is a single token that
    appears exactly once in the context followed by an answer span of 2
    tokens; the model predicts (start, end).  Returns (tokens, starts, ends).
    Layout: [BOS, ctx…, SEP, qtok]."""
    rng = np.random.default_rng(seed)
    toks = np.full((n, seq), PAD, np.int32)
    starts = np.empty(n, np.int32)
    ends = np.empty(n, np.int32)
    clen = seq - 3
    for i in range(n):
        ctx = rng.integers(NLU_CONTENT_LO, vocab, size=clen)
        q = int(rng.integers(NLU_CONTENT_LO, vocab))
        ctx[ctx == q] = (q + 1 - NLU_CONTENT_LO) % (vocab - NLU_CONTENT_LO) + NLU_CONTENT_LO
        pos = int(rng.integers(0, clen - 2))
        ctx[pos] = q
        toks[i, 0] = BOS
        toks[i, 1 : 1 + clen] = ctx
        toks[i, 1 + clen] = SEP
        toks[i, 2 + clen] = q
        starts[i] = 1 + pos + 1   # answer = the 2 tokens after the marker
        ends[i] = starts[i] + 1
    return toks, starts, ends


def _synonym_involution(seed: int) -> np.ndarray:
    """A fixed involution over content tokens acting as 'synonyms'."""
    rng = np.random.default_rng(seed + 77)
    ids = np.arange(NLU_VOCAB)
    content = ids[NLU_CONTENT_LO:]
    perm = rng.permutation(content)
    syn = ids.copy()
    half = len(content) // 2
    a, b = perm[:half], perm[half : 2 * half]
    syn[a], syn[b] = b, a
    return syn


# ---------------------------------------------------------------------------
# Split helpers
# ---------------------------------------------------------------------------

def train_eval_split(x, y, n_eval: int):
    return (x[:-n_eval], y[:-n_eval]), (x[-n_eval:], y[-n_eval:])
