"""Layer-2 computation graphs lowered to HLO for the Rust coordinator.

Every graph here becomes one `artifacts/*.hlo.txt` executable with a flat,
manifest-documented input/output signature (the Rust runtime matches buffers
by position).  Graph families, per model:

  embed_{m}                    tokens → h₀                         (fp)
  fp_{m}_{u}                   x → y  (fp unit, weights baked)     (fp)
  recon_{m}_{u}_{meth}_{mode}  one reconstruction Adam step        (PTQ)
  q_{m}_{u}_{meth}_{mode}      x̃ → ỹ through the quantized unit    (PTQ)
  qw_{m}_{u}_{meth}            learned params → (Ŵ, integer codes) (export)
  head_{m}                     h → logits / per-seq NLL            (fp)

`mode` ∈ {"w", "wa"}: weight-only versus weight+activation quantization
(LSQ steps learned jointly, QDrop dropout via an in-graph bernoulli mask).

Bit-widths are **runtime inputs** (qmin/qmax scalars), so one graph serves
every row of the paper's tables; per-channel vs per-tensor s1 is a static
property chosen per model (only the LLaMA analog uses per-channel weights).

Parameter packing: `ParamPack` fixes the flat ordering of every learnable
tensor (layer params in `QUnit.layers` order with canonically-ordered keys,
then activation steps per site).  The same ordering is used for the Adam
moment buffers, the init data shipped to Rust, and the manifest signature.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import models as M
from compile import quant as Q
from compile.kernels import ref

PARAM_KEY_ORDER = ("s1", "zp", "s2", "s3", "s4", "v")


# ---------------------------------------------------------------------------
# Canonical 2D views of unit layers
# ---------------------------------------------------------------------------

@dataclass
class LayerView:
    """A quantizable layer in its canonical 2D view."""
    name: str
    kind: str                      # conv | dwconv | linear
    w2d: jnp.ndarray               # (r, c)
    bias: jnp.ndarray
    conv_shape: Optional[Tuple[int, int, int, int]]  # HWIO, None for linear
    stride: int

    @property
    def rc(self):
        return self.w2d.shape


def layer_views(model: M.QModel, params, unit: M.QUnit) -> List[LayerView]:
    views = []
    if unit.kind == "head_fc":
        w = params["head"]["fc_w"]
        b = params["head"]["fc_b"]
        (l0,) = unit.layers
        views.append(LayerView(l0.name, "linear", w, b, None, 1))
        return views
    up = params["units"][unit.name]
    for l in unit.layers:
        p = up["layers"][l.name]
        if l.kind == "linear":
            views.append(LayerView(l.name, l.kind, p["w"], p["b"], None, l.stride))
        else:
            w2d = Q.conv_to_2d(p["w"])
            views.append(LayerView(l.name, l.kind, w2d, p["b"], tuple(p["w"].shape), l.stride))
    return views


def w2d_to_native(view: LayerView, w2d):
    if view.conv_shape is None:
        return w2d
    return Q.conv_from_2d(w2d, view.conv_shape)


# ---------------------------------------------------------------------------
# Parameter packing
# ---------------------------------------------------------------------------

@dataclass
class PackEntry:
    name: str          # "<layer>.<key>" or "act<i>.step" / "act<i>.zp"
    shape: Tuple[int, ...]
    learnable: bool


class ParamPack:
    """Deterministic flat ordering of a unit's learnable parameters."""

    def __init__(self, entries: List[PackEntry]):
        self.entries = entries

    @staticmethod
    def build(method: str, views: List[LayerView], mode: str,
              n_act_sites: int, per_channel: bool) -> "ParamPack":
        entries: List[PackEntry] = []
        lkeys = set(Q.learnable_keys(method))
        for v in views:
            r, c = v.rc
            p_shapes = {
                "s1": (r, 1) if per_channel else (1, 1),
                "zp": (r, 1) if per_channel else (1, 1),
            }
            if method in ("flexround", "flexround_fixed_s1", "flexround_no_s34",
                          "adaquant_flexround"):
                p_shapes.update({"s2": (r, c), "s3": (r, 1), "s4": (1, c)})
            if method in ("adaround", "adaquant", "adaquant_flexround"):
                p_shapes["v"] = (r, c)
            for k in PARAM_KEY_ORDER:
                if k in p_shapes:
                    entries.append(PackEntry(f"{v.name}.{k}", p_shapes[k], k in lkeys))
        if mode == "wa":
            for i in range(n_act_sites):
                entries.append(PackEntry(f"act{i}.step", (1, 1), True))
                entries.append(PackEntry(f"act{i}.zp", (1, 1), False))
        self_ = ParamPack(entries)
        return self_

    def unflatten(self, flat: List) -> Tuple[List[Dict], Dict[int, Dict]]:
        """flat arrays → (per-layer param dicts, act-site dicts)."""
        per_layer: List[Dict] = []
        acts: Dict[int, Dict] = {}
        cur: Dict[str, jnp.ndarray] = {}
        cur_layer = None
        i = 0
        for e in self.entries:
            owner, key = e.name.split(".")
            if owner.startswith("act"):
                acts.setdefault(int(owner[3:]), {})[key] = flat[i]
            else:
                if owner != cur_layer:
                    if cur_layer is not None:
                        per_layer.append(cur)
                    cur, cur_layer = {}, owner
                cur[key] = flat[i]
            i += 1
        if cur_layer is not None:
            per_layer.append(cur)
        return per_layer, acts

    def init_values(self, method: str, views: List[LayerView], bits: int,
                    symmetric: bool, per_channel: bool,
                    act_init: Optional[List[Tuple[float, float]]] = None,
                    abits: int = 8) -> List[np.ndarray]:
        """Initial values in pack order (the data Rust feeds to step 0)."""
        by_layer = {}
        for v in views:
            kh_kw = 1 if v.conv_shape is None else v.conv_shape[0] * v.conv_shape[1]
            cin = v.rc[1] // kh_kw
            by_layer[v.name] = Q.init_params(
                method, v.w2d, bits, symmetric, per_channel,
                conv_cin=cin, ksize=kh_kw)
        out = []
        for e in self.entries:
            owner, key = e.name.split(".")
            if owner.startswith("act"):
                lo, hi = act_init[int(owner[3:])]
                qmin_a, qmax_a = ref.qrange(abits, False)
                step = max((hi - lo) / (qmax_a - qmin_a), 1e-6)
                zp = min(max(round(-lo / step), qmin_a), qmax_a)
                val = np.full((1, 1), step if key == "step" else zp, np.float32)
            else:
                val = np.asarray(by_layer[owner][key], np.float32).reshape(e.shape)
            out.append(val)
        return out


# ---------------------------------------------------------------------------
# Quantized unit forward
# ---------------------------------------------------------------------------

def n_act_sites(unit: M.QUnit) -> int:
    return len(unit.layers) if unit.kind != "head_fc" else 1


def quantized_unit_fwd(model: M.QModel, params, unit: M.QUnit, method: str,
                       mode: str, pack: ParamPack, views: List[LayerView],
                       impl: str = "pallas", use_qdrop: bool = True):
    """Returns f(flat_params, x, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, key) → y.

    Weights (full-precision) are baked as constants; quantization parameters
    arrive flat.  In "wa" mode every layer input passes through LSQ
    fake-quant, optionally QDrop-mixed with its full-precision value."""
    aux = None
    if unit.kind == "txl":
        aux = params["units"][unit.name]["aux"]

    def fwd(flat, x, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, key):
        per_layer, acts = pack.unflatten(flat)
        what_native = []
        for v, p in zip(views, per_layer):
            w_hat2d = Q.fake_quant(method, v.w2d, p, qmin_w, qmax_w, impl=impl)
            what_native.append(w2d_to_native(v, w_hat2d))

        def actq(t, i):
            if mode != "wa":
                return t
            a = acts[i]
            if impl == "jnp":
                tq = ref.lsq_act(t, a["step"].reshape(()), qmin_a, qmax_a,
                                 a["zp"].reshape(()))
            else:
                tq = Q.quant_act(t, a["step"], jax.lax.stop_gradient(a["zp"]),
                                 qmin_a, qmax_a)
            if not use_qdrop:
                # q/eval executables run with drop_p = 0: the mixing is the
                # identity, and keeping the constant-key threefry ops in the
                # graph crashes the xla_extension 0.5.1 CPU compiler.
                return tq
            k = jax.random.fold_in(key, i)
            keep = jax.random.bernoulli(k, 1.0 - drop_p, shape=t.shape)
            return jnp.where(keep, tq, t)

        if unit.kind == "head_fc":
            pooled = x.mean(axis=(1, 2)) if x.ndim == 4 else x
            return M.linear(actq(pooled, 0), what_native[0], views[0].bias)
        bs = [v.bias for v in views]
        return M.apply_unit(unit, what_native, bs, aux, x, actq=actq)

    return fwd


def fp_unit_fwd(model: M.QModel, params, unit: M.QUnit):
    views = layer_views(model, params, unit)
    aux = params["units"][unit.name]["aux"] if unit.kind == "txl" else None

    def fwd(x):
        if unit.kind == "head_fc":
            pooled = x.mean(axis=(1, 2)) if x.ndim == 4 else x
            return M.linear(pooled, views[0].w2d, views[0].bias)
        ws = [w2d_to_native(v, v.w2d) for v in views]
        bs = [v.bias for v in views]
        return M.apply_unit(unit, ws, bs, aux, x)

    return fwd


# ---------------------------------------------------------------------------
# Reconstruction step (fwd + bwd + Adam, one executable)
# ---------------------------------------------------------------------------

def recon_step_fn(model: M.QModel, params, unit: M.QUnit, method: str,
                  mode: str, pack: ParamPack, views: List[LayerView]):
    """One PTQ iteration:  L = ‖Ŷ − Y‖²  (+ β·f_reg for AdaRound),
    grads via the custom-VJP STE ops, in-graph Adam, positivity clamp.

    Signature (flat):
      inputs : x̃, y, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, beta, lr, t,
               seed, *params, *m, *v
      outputs: loss, *params', *m', *v'
    """
    fwd = quantized_unit_fwd(model, params, unit, method, mode, pack, views)
    learn_mask = [e.learnable for e in pack.entries]

    def loss_fn(flat, x, y, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, beta, key):
        yhat = fwd(flat, x, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, key)
        loss = jnp.mean((yhat - y) ** 2)
        if method == "adaround":
            per_layer, _ = pack.unflatten(flat)
            reg = sum(ref.adaround_reg(p["v"], beta) for p in per_layer)
            loss = loss + 0.01 * reg / sum(v.w2d.size for v in views)
        return loss

    def step(x, y, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, beta, lr, t, seed,
             *state):
        n = len(pack.entries)
        flat = list(state[:n])
        m = list(state[n : 2 * n])
        v = list(state[2 * n :])
        key = jax.random.PRNGKey(seed)
        loss, grads = jax.value_and_grad(loss_fn)(
            flat, x, y, qmin_w, qmax_w, qmin_a, qmax_a, drop_p, beta, key)
        new_flat, new_m, new_v = [], [], []
        b1t = 1.0 - Q.ADAM_B1 ** t
        b2t = 1.0 - Q.ADAM_B2 ** t
        for p, g, mm, vv, lm, e in zip(flat, grads, m, v, learn_mask, pack.entries):
            if not lm:
                new_flat.append(p)
                new_m.append(mm)
                new_v.append(vv)
                continue
            m2 = Q.ADAM_B1 * mm + (1 - Q.ADAM_B1) * g
            v2 = Q.ADAM_B2 * vv + (1 - Q.ADAM_B2) * g * g
            p2 = p - lr * (m2 / b1t) / (jnp.sqrt(v2 / b2t) + Q.ADAM_EPS)
            base = e.name.split(".")[1]
            if base in ("s1", "s2", "s3", "s4") or base == "step":
                p2 = jnp.maximum(p2, 1e-6)
            new_flat.append(p2)
            new_m.append(m2)
            new_v.append(v2)
        return (loss, *new_flat, *new_m, *new_v)

    return step


# ---------------------------------------------------------------------------
# Quantized-weight export graph (Ŵ + integer codes, for figures/analysis)
# ---------------------------------------------------------------------------

def qw_export_fn(views: List[LayerView], method: str, pack: ParamPack,
                 impl: str = "jnp"):
    def export(qmin_w, qmax_w, *flat):
        per_layer, _ = pack.unflatten(list(flat))
        outs = []
        for v, p in zip(views, per_layer):
            outs.append(Q.fake_quant(method, v.w2d, p, qmin_w, qmax_w, impl=impl))
            outs.append(Q.quant_int_codes(method, v.w2d, p, qmin_w, qmax_w, impl=impl))
        return tuple(outs)

    return export


# ---------------------------------------------------------------------------
# Model-level fp graphs: embedding and heads
# ---------------------------------------------------------------------------

def embed_fn(model: M.QModel, params):
    tok = params["pre"]["tok"]
    pos = params["pre"]["pos"]

    def f(tokens):
        return tok[tokens] + pos[None, : tokens.shape[1]]

    return f


def head_fn(model: M.QModel, params, task: Optional[str] = None):
    """Final (full-precision) head.

      lm        : (h, tokens) → (nll_sum_per_seq, tok_count_per_seq)
      cls       : (h,)        → logits
      span      : (h,)        → (start_logits, end_logits)
      multi     : per-task head selected by `task` ("span" → span head)
      cnn heads are units (head_fc), not handled here.
    """
    hd = model.meta["head"]
    if hd == "multi":
        hd = "span" if task == "span" else "cls_multi"
    ln_g, ln_b = params["head"]["ln_g"], params["head"]["ln_b"]

    if hd == "lm":
        ow, ob = params["head"]["out_w"], params["head"]["out_b"]

        def f(h, tokens):
            hn = M.layernorm(h, ln_g, ln_b)
            logits = M.linear(hn, ow, ob)
            tgt = tokens[:, 1:]
            lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
            nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
            mask = (tgt != 0).astype(jnp.float32)
            return (nll * mask).sum(axis=1), mask.sum(axis=1)

        return f

    if hd == "cls":
        ow, ob = params["head"]["out_w"], params["head"]["out_b"]

        def f(h):
            hn = M.layernorm(h, ln_g, ln_b)
            return M.linear(hn.mean(axis=1), ow, ob)

        return f

    if hd == "cls_multi":
        ow = params["head"][f"{task}_w"]
        ob = params["head"][f"{task}_b"]

        def f(h):
            hn = M.layernorm(h, ln_g, ln_b)
            return M.linear(hn.mean(axis=1), ow, ob)

        return f

    if hd == "span":
        key = "span_" if model.meta["head"] == "multi" else ""
        sw = params["head"][f"{key}start_w"]
        ew = params["head"][f"{key}end_w"]

        def f(h):
            hn = M.layernorm(h, ln_g, ln_b)
            return (hn @ sw.T)[..., 0], (hn @ ew.T)[..., 0]

        return f

    raise ValueError(hd)


# ---------------------------------------------------------------------------
# Activation-range calibration (runs at AOT time, full precision)
# ---------------------------------------------------------------------------

def calibrate_act_ranges(model: M.QModel, params, unit: M.QUnit, x) -> List[Tuple[float, float]]:
    """(lo, hi) per act site from the fp forward on calibration data; used to
    initialize the LSQ step (asymmetric per-tensor, as in the paper)."""
    views = layer_views(model, params, unit)
    ranges: List[Tuple[float, float]] = [(0.0, 0.0)] * n_act_sites(unit)

    def probe(t, i):
        lo = float(jnp.min(t))
        hi = float(jnp.max(t))
        plo, phi = ranges[i]
        ranges[i] = (min(plo, lo), max(phi, hi))
        return t

    aux = params["units"][unit.name]["aux"] if unit.kind == "txl" else None
    if unit.kind == "head_fc":
        pooled = x.mean(axis=(1, 2)) if x.ndim == 4 else x
        probe(pooled, 0)
    else:
        ws = [w2d_to_native(v, v.w2d) for v in views]
        bs = [v.bias for v in views]
        M.apply_unit(unit, ws, bs, aux, x, actq=probe)
    return ranges


# ---------------------------------------------------------------------------
# In-python PTQ driver (used by tests + as the oracle for the Rust engine)
# ---------------------------------------------------------------------------

def python_recon_unit(model, params, unit, method, mode, x_tilde, y_target,
                      bits_w, iters, lr, per_channel=False, symmetric=True,
                      abits=8, drop_p=0.0, seed=0,
                      act_init=None):
    """Pure-python reference of the Rust reconstruction loop (same graphs,
    jit-executed in-process).  Returns (final loss, learned flat params)."""
    views = layer_views(model, params, unit)
    pack = ParamPack.build(method, views, mode, n_act_sites(unit), per_channel)
    if act_init is None and mode == "wa":
        act_init = calibrate_act_ranges(model, params, unit, x_tilde)
    flat = [jnp.asarray(a) for a in pack.init_values(
        method, views, bits_w, symmetric, per_channel, act_init, abits)]
    m = [jnp.zeros_like(a) for a in flat]
    v = [jnp.zeros_like(a) for a in flat]
    step = jax.jit(recon_step_fn(model, params, unit, method, mode, pack, views))
    qmin_w, qmax_w = ref.qrange(bits_w, symmetric)
    qmin_a, qmax_a = ref.qrange(abits, False)
    loss = None
    for t in range(1, iters + 1):
        out = step(x_tilde, y_target,
                   float(qmin_w), float(qmax_w), float(qmin_a), float(qmax_a),
                   float(drop_p), _beta(t, iters), lr, float(t),
                   np.int32(seed * 100003 + t), *flat, *m, *v)
        loss = out[0]
        n = len(flat)
        flat = list(out[1 : 1 + n])
        m = list(out[1 + n : 1 + 2 * n])
        v = list(out[1 + 2 * n :])
    return float(loss), flat, pack


def _beta(t, iters, beta_hi=20.0, beta_lo=2.0, warmup=0.2):
    """AdaRound β annealing: constant during warmup, then cosine hi→lo."""
    if t < warmup * iters:
        return beta_hi
    frac = (t - warmup * iters) / max(1.0, (1 - warmup) * iters)
    return beta_lo + 0.5 * (beta_hi - beta_lo) * (1 + np.cos(np.pi * min(frac, 1.0)))
