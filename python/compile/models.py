"""Model zoo — the pre-trained networks that get post-training-quantized.

The zoo mirrors the paper's experimental matrix at laptop scale (see
DESIGN.md "Substitutions"):

  CNNs (ImageNet analogs)         : tinyresnet_a (ResNet-18), tinyresnet_b
                                    (ResNet-50 bottlenecks), tinymobilenet
                                    (MobileNetV2 inverted residuals, ReLU6)
  Encoders (BERT/GPT-Neo on GLUE) : enc_small, enc_base (+ span head variant)
  Decoders (GPT-Neo/OPT/GPT-2)    : dec_small, dec_med, dec_lora (LoRA-merged)
  LLM analog (LLaMA)              : llm_mini

Everything is expressed as a normalized **QModel**: an ordered list of
reconstruction units (`QUnit`), each holding its quantizable layers
(`QLayer`) plus full-precision auxiliaries (LayerNorm/BN-folded biases).
`compile.graphs` builds the fp/quantized unit functions from this structure,
`compile.train` trains into it, and `compile.aot` serializes it for the Rust
coordinator.

Layout conventions: images NHWC, conv weights HWIO, linear weights (out, in),
token activations (batch, seq, d).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D

# ---------------------------------------------------------------------------
# Normalized quantization-facing model structure
# ---------------------------------------------------------------------------


@dataclass
class QLayer:
    name: str
    kind: str                  # "conv" | "dwconv" | "linear"
    wshape: Tuple[int, ...]    # conv HWIO / linear (out, in)
    stride: int = 1
    relu6: bool = False        # activation following this layer inside the unit


@dataclass
class QUnit:
    name: str
    kind: str                  # stem_conv | res_block | bottleneck_block |
                               # invres_block | head_conv | txl
    layers: List[QLayer]
    meta: Dict = field(default_factory=dict)
    bits_override: Optional[int] = None   # CNN first/last units pin 8-bit


@dataclass
class QModel:
    name: str
    kind: str                  # "cnn" | "encoder" | "decoder"
    units: List[QUnit]
    meta: Dict = field(default_factory=dict)

    def unit(self, name: str) -> QUnit:
        for u in self.units:
            if u.name == name:
                return u
        raise KeyError(name)


# ---------------------------------------------------------------------------
# Primitive ops
# ---------------------------------------------------------------------------

def conv2d(x, w, b, stride=1, groups=1):
    """NHWC x, HWIO w, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + b


def linear(x, w, b):
    """x (..., in) · w(out, in)ᵀ + b."""
    return x @ w.T + b


def layernorm(x, g, b, eps=1e-5):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def attention(q, k, v, causal: bool, nheads: int):
    """q/k/v: (B, T, D) → (B, T, D), multi-head with D = nheads·dh."""
    b, t, dmodel = q.shape
    dh = dmodel // nheads

    def split(x):
        return x.reshape(b, t, nheads, dh).transpose(0, 2, 1, 3)

    qh, kh, vh = split(q), split(k), split(v)
    att = (qh @ kh.transpose(0, 1, 3, 2)) / jnp.sqrt(dh).astype(q.dtype)
    if causal:
        mask = jnp.tril(jnp.ones((t, t), bool))
        att = jnp.where(mask, att, jnp.float32(-1e9))
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ vh).transpose(0, 2, 1, 3).reshape(b, t, dmodel)
    return out


def relu(x):
    return jnp.maximum(x, 0.0)


def relu6(x):
    return jnp.clip(x, 0.0, 6.0)


def gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608 * (x + 0.044715 * x**3)))


# ---------------------------------------------------------------------------
# Unit topologies — one forward function per unit kind.
#
# `ws`/`bs` are the (possibly fake-quantized) layer weights in QUnit.layers
# order; `aux` carries full-precision constants (LN params); `actq` is a
# callable applied to the *input of every quantizable layer* (identity when
# activations are kept fp, LSQ fake-quant + optional QDrop during W/A PTQ).
# ---------------------------------------------------------------------------

def _act(x, layer: QLayer):
    return relu6(x) if layer.relu6 else relu(x)


def apply_unit(unit: QUnit, ws, bs, aux, x, actq=None):
    actq = actq or (lambda t, i: t)
    k = unit.kind
    if k == "stem_conv":
        (l0,) = unit.layers
        return _act(conv2d(actq(x, 0), ws[0], bs[0], l0.stride), l0)
    if k == "res_block":
        # conv1 → relu → conv2 (+ projection shortcut when shapes change)
        y = relu(conv2d(actq(x, 0), ws[0], bs[0], unit.layers[0].stride))
        y = conv2d(actq(y, 1), ws[1], bs[1], unit.layers[1].stride)
        if len(unit.layers) == 3:
            sc = conv2d(actq(x, 2), ws[2], bs[2], unit.layers[2].stride)
        else:
            sc = x
        return relu(y + sc)
    if k == "bottleneck_block":
        y = relu(conv2d(actq(x, 0), ws[0], bs[0], 1))
        y = relu(conv2d(actq(y, 1), ws[1], bs[1], unit.layers[1].stride))
        y = conv2d(actq(y, 2), ws[2], bs[2], 1)
        if len(unit.layers) == 4:
            sc = conv2d(actq(x, 3), ws[3], bs[3], unit.layers[3].stride)
        else:
            sc = x
        return relu(y + sc)
    if k == "invres_block":
        # 1×1 expand → act → depthwise 3×3 → act → 1×1 project (+skip);
        # the activation follows each layer's relu6 flag so the CLE
        # preprocessing (ReLU6 → ReLU) changes the executed topology too.
        y = _act(conv2d(actq(x, 0), ws[0], bs[0], 1), unit.layers[0])
        y = _act(conv2d(actq(y, 1), ws[1], bs[1], unit.layers[1].stride,
                        groups=y.shape[-1]), unit.layers[1])
        y = conv2d(actq(y, 2), ws[2], bs[2], 1)
        if unit.meta.get("skip", False):
            y = y + x
        return y
    if k == "head_conv":
        (l0,) = unit.layers
        return _act(conv2d(actq(x, 0), ws[0], bs[0], 1), l0)
    if k == "txl":
        # pre-LN transformer layer; aux = (ln1_g, ln1_b, ln2_g, ln2_b)
        ln1g, ln1b, ln2g, ln2b = aux
        h = layernorm(x, ln1g, ln1b)
        q = linear(actq(h, 0), ws[0], bs[0])
        kk = linear(actq(h, 1), ws[1], bs[1])
        v = linear(actq(h, 2), ws[2], bs[2])
        a = attention(q, kk, v, unit.meta["causal"], unit.meta["nheads"])
        x = x + linear(actq(a, 3), ws[3], bs[3])
        h2 = layernorm(x, ln2g, ln2b)
        f = gelu(linear(actq(h2, 4), ws[4], bs[4]))
        return x + linear(actq(f, 5), ws[5], bs[5])
    raise ValueError(f"unknown unit kind {k!r}")


# ---------------------------------------------------------------------------
# CNN model specs
# ---------------------------------------------------------------------------

def _conv_layer(name, kh, cin, cout, stride=1, relu6_=False, dw=False):
    if dw:
        return QLayer(name, "dwconv", (kh, kh, 1, cout), stride, relu6_)
    return QLayer(name, "conv", (kh, kh, cin, cout), stride, relu6_)


def tinyresnet_a() -> QModel:
    """ResNet-18 analog: basic residual blocks, 2 stages, |W| < 1 regime."""
    units = [
        QUnit("stem", "stem_conv", [_conv_layer("conv", 3, 3, 16)], bits_override=8),
        QUnit("s1b1", "res_block",
              [_conv_layer("conv1", 3, 16, 16), _conv_layer("conv2", 3, 16, 16)]),
        QUnit("s1b2", "res_block",
              [_conv_layer("conv1", 3, 16, 16), _conv_layer("conv2", 3, 16, 16)]),
        QUnit("s2b1", "res_block",
              [_conv_layer("conv1", 3, 16, 32, stride=2),
               _conv_layer("conv2", 3, 32, 32),
               _conv_layer("proj", 1, 16, 32, stride=2)]),
        QUnit("s2b2", "res_block",
              [_conv_layer("conv1", 3, 32, 32), _conv_layer("conv2", 3, 32, 32)]),
    ]
    head = QUnit("head", "head_fc", [QLayer("fc", "linear", (D.IMG_CLASSES, 32))],
                 bits_override=8)
    units.append(head)
    return QModel("tinyresnet_a", "cnn", units,
                  meta={"input": "image", "classes": D.IMG_CLASSES})


def tinyresnet_b() -> QModel:
    """ResNet-50 analog: bottleneck blocks (1×1 → 3×3 → 1×1)."""
    def bottleneck(name, cin, cmid, cout, stride=1, proj=False):
        layers = [
            _conv_layer("conv1", 1, cin, cmid),
            _conv_layer("conv2", 3, cmid, cmid, stride=stride),
            _conv_layer("conv3", 1, cmid, cout),
        ]
        if proj:
            layers.append(_conv_layer("proj", 1, cin, cout, stride=stride))
        return QUnit(name, "bottleneck_block", layers)

    units = [
        QUnit("stem", "stem_conv", [_conv_layer("conv", 3, 3, 16)], bits_override=8),
        bottleneck("s1b1", 16, 8, 32, proj=True),
        bottleneck("s1b2", 32, 8, 32),
        bottleneck("s2b1", 32, 16, 64, stride=2, proj=True),
        bottleneck("s2b2", 64, 16, 64),
        QUnit("head", "head_fc", [QLayer("fc", "linear", (D.IMG_CLASSES, 64))],
              bits_override=8),
    ]
    return QModel("tinyresnet_b", "cnn", units,
                  meta={"input": "image", "classes": D.IMG_CLASSES})


def tinymobilenet() -> QModel:
    """MobileNetV2 analog: inverted residuals, depthwise convs, ReLU6 —
    the architecture whose large-magnitude weights exercise FlexRound's
    flexibility claim (paper Fig. 3a)."""
    def invres(name, cin, cout, stride=1, exp=4):
        cmid = cin * exp
        return QUnit(name, "invres_block", [
            _conv_layer("expand", 1, cin, cmid, relu6_=True),
            _conv_layer("dw", 3, cmid, cmid, stride=stride, relu6_=True, dw=True),
            _conv_layer("project", 1, cmid, cout),
        ], meta={"skip": cin == cout and stride == 1})

    units = [
        QUnit("stem", "stem_conv", [_conv_layer("conv", 3, 3, 8, relu6_=True)],
              bits_override=8),
        invres("b1", 8, 16),
        invres("b2", 16, 16),
        invres("b3", 16, 32, stride=2),
        invres("b4", 32, 32),
        QUnit("hconv", "head_conv", [_conv_layer("conv", 1, 32, 64, relu6_=True)]),
        QUnit("head", "head_fc", [QLayer("fc", "linear", (D.IMG_CLASSES, 64))],
              bits_override=8),
    ]
    return QModel("tinymobilenet", "cnn", units,
                  meta={"input": "image", "classes": D.IMG_CLASSES})


# ---------------------------------------------------------------------------
# Transformer model specs
# ---------------------------------------------------------------------------

def _txl_unit(name, d, nheads, causal, dff=None) -> QUnit:
    dff = dff or 4 * d
    return QUnit(name, "txl", [
        QLayer("wq", "linear", (d, d)),
        QLayer("wk", "linear", (d, d)),
        QLayer("wv", "linear", (d, d)),
        QLayer("wo", "linear", (d, d)),
        QLayer("fc1", "linear", (dff, d)),
        QLayer("fc2", "linear", (d, dff)),
    ], meta={"causal": causal, "nheads": nheads, "d": d, "dff": dff})


def transformer(name: str, kind: str, vocab: int, seq: int, d: int,
                nlayers: int, nheads: int, head: str, nclasses: int = 2) -> QModel:
    causal = kind == "decoder"
    units = [_txl_unit(f"l{i}", d, nheads, causal) for i in range(nlayers)]
    return QModel(name, kind, units, meta={
        "input": "tokens", "vocab": vocab, "seq": seq, "d": d,
        "nheads": nheads, "head": head, "nclasses": nclasses,
    })


def enc_small():
    """BERT-base analog: multi-task NLU encoder (all GLUE-analog heads)."""
    return transformer("enc_small", "encoder", D.NLU_VOCAB, D.NLU_SEQ,
                       48, 2, 2, "multi")


def enc_base():
    """BERT-large / GPT-Neo analog: the bigger NLU encoder."""
    return transformer("enc_base", "encoder", D.NLU_VOCAB, D.NLU_SEQ,
                       96, 3, 4, "multi")


def dec_small(corpus="lm-a"):
    return transformer(f"dec_small_{corpus.replace('-', '')}", "decoder",
                       D.LM_VOCAB, D.LM_SEQ, 48, 2, 2, "lm")


def dec_med(corpus="lm-a"):
    return transformer(f"dec_med_{corpus.replace('-', '')}", "decoder",
                       D.LM_VOCAB, D.LM_SEQ, 96, 3, 4, "lm")


def dec_lora():
    return transformer("dec_lora", "decoder", D.D2T_VOCAB, D.D2T_SEQ, 48, 2, 2, "lm")


def llm_mini():
    return transformer("llm_mini", "decoder", D.LM_VOCAB, D.LM_SEQ, 128, 4, 4, "lm")


def _alt(builder, name):
    """Alternate-checkpoint variants (Tables 8/9: the 'official PyTorch'
    pre-trained models) — same architecture, different training seed."""
    def build():
        m = builder()
        m.name = name
        return m
    return build


MODEL_BUILDERS = {
    "tinyresnet_a": tinyresnet_a,
    "tinyresnet_b": tinyresnet_b,
    "tinymobilenet": tinymobilenet,
    "tinyresnet_a_alt": _alt(tinyresnet_a, "tinyresnet_a_alt"),
    "tinyresnet_b_alt": _alt(tinyresnet_b, "tinyresnet_b_alt"),
    "tinymobilenet_alt": _alt(tinymobilenet, "tinymobilenet_alt"),
    "enc_small": enc_small,
    "enc_base": enc_base,
    "dec_small_lma": lambda: dec_small("lm-a"),
    "dec_small_lmb": lambda: dec_small("lm-b"),
    "dec_med_lma": lambda: dec_med("lm-a"),
    "dec_med_lmb": lambda: dec_med("lm-b"),
    "dec_lora": dec_lora,
    "llm_mini": llm_mini,
}


# ---------------------------------------------------------------------------
# Parameter init / forward pass over the whole model
#
# Params pytree:
#   {"units": {uname: {"layers": {lname: {"w","b"}}, "aux": [...], "bn": {...}}},
#    "pre": {...embedding...}, "head": {...}}
# BN is train-time only; `fold_bn` bakes it into (w, b) at export.
# ---------------------------------------------------------------------------

def init_model(model: QModel, seed: int, init_gain: float = 1.0):
    rng = np.random.default_rng(seed)
    params = {"units": {}, "pre": {}, "head": {}}
    for u in model.units:
        if u.kind == "head_fc":
            (l0,) = u.layers
            fan_in = l0.wshape[1]
            params["head"]["fc_w"] = _he(rng, l0.wshape, fan_in)
            params["head"]["fc_b"] = np.zeros(l0.wshape[0], np.float32)
            continue
        up = {"layers": {}, "aux": [], "bn": {}}
        for l in u.layers:
            if l.kind == "linear":
                fan_in = l.wshape[1]
            elif l.kind == "dwconv":
                fan_in = l.wshape[0] * l.wshape[1]
            else:
                fan_in = l.wshape[0] * l.wshape[1] * l.wshape[2]
            gain = init_gain if l.kind == "dwconv" else 1.0
            up["layers"][l.name] = {
                "w": _he(rng, l.wshape, fan_in, gain),
                "b": np.zeros(_cout(l), np.float32),
            }
            if model.kind == "cnn":
                up["bn"][l.name] = _bn_init(_cout(l))
        if u.kind == "txl":
            d = u.meta["d"]
            up["aux"] = [np.ones(d, np.float32), np.zeros(d, np.float32),
                         np.ones(d, np.float32), np.zeros(d, np.float32)]
        params["units"][u.name] = up
    if model.meta.get("input") == "tokens":
        v, s, d = model.meta["vocab"], model.meta["seq"], model.meta["d"]
        params["pre"]["tok"] = (rng.normal(0, 0.02, (v, d))).astype(np.float32)
        params["pre"]["pos"] = (rng.normal(0, 0.02, (s, d))).astype(np.float32)
        params["head"]["ln_g"] = np.ones(d, np.float32)
        params["head"]["ln_b"] = np.zeros(d, np.float32)
        hd = model.meta["head"]
        if hd == "lm":
            params["head"]["out_w"] = (rng.normal(0, 0.02, (v, d))).astype(np.float32)
            params["head"]["out_b"] = np.zeros(v, np.float32)
        elif hd == "cls":
            nc = model.meta["nclasses"]
            params["head"]["out_w"] = (rng.normal(0, 0.05, (nc, d))).astype(np.float32)
            params["head"]["out_b"] = np.zeros(nc, np.float32)
        elif hd == "span":
            params["head"]["start_w"] = (rng.normal(0, 0.05, (1, d))).astype(np.float32)
            params["head"]["end_w"] = (rng.normal(0, 0.05, (1, d))).astype(np.float32)
        elif hd == "multi":
            # multi-task encoder: one classification head per NLU task plus a
            # span-extraction head (SQuAD analog); the backbone is shared and
            # quantized once, as in the paper's per-task fine-tuned BERTs.
            for task in D.NLU_TASKS:
                params["head"][f"{task}_w"] = (rng.normal(0, 0.05, (2, d))).astype(np.float32)
                params["head"][f"{task}_b"] = np.zeros(2, np.float32)
            params["head"]["span_start_w"] = (rng.normal(0, 0.05, (1, d))).astype(np.float32)
            params["head"]["span_end_w"] = (rng.normal(0, 0.05, (1, d))).astype(np.float32)
    return jax.tree_util.tree_map(jnp.asarray, params)


def _he(rng, shape, fan_in, gain=1.0):
    return (rng.normal(0, gain * np.sqrt(2.0 / fan_in), shape)).astype(np.float32)


def _cout(l: QLayer):
    return l.wshape[0] if l.kind == "linear" else l.wshape[3]


def _bn_init(c):
    return {"g": np.ones(c, np.float32), "b": np.zeros(c, np.float32),
            "mean": np.zeros(c, np.float32), "var": np.ones(c, np.float32)}


# --- training-time forward (with BN batch stats for CNNs) -------------------

def _bn_apply(y, bn, train: bool, eps=1e-5):
    if train:
        mu = y.mean(axis=(0, 1, 2))
        var = y.var(axis=(0, 1, 2))
    else:
        mu, var = bn["mean"], bn["var"]
    yn = (y - mu) / jnp.sqrt(var + eps)
    return yn * bn["g"] + bn["b"], (mu, var)


def forward_train(model: QModel, params, x, train: bool = True, task: str = None):
    """Full forward pass for pre-training.  CNNs run conv→BN→act inside each
    unit (BN folded away at export); transformers run the QModel topology
    directly.  Returns (output, batch_stats) where batch_stats maps
    unit/layer → (mean, var) for EMA tracking."""
    stats = {}
    if model.kind == "cnn":
        h = x
        for u in model.units:
            if u.kind == "head_fc":
                continue
            up = params["units"][u.name]
            h = _apply_cnn_unit_train(u, up, h, train, stats)
        h = h.mean(axis=(1, 2))
        logits = linear(h, params["head"]["fc_w"], params["head"]["fc_b"])
        return logits, stats
    # transformers
    emb = params["pre"]["tok"][x] + params["pre"]["pos"][None, : x.shape[1]]
    h = emb
    for u in model.units:
        up = params["units"][u.name]
        ws = [up["layers"][l.name]["w"] for l in u.layers]
        bs = [up["layers"][l.name]["b"] for l in u.layers]
        h = apply_unit(u, ws, bs, up["aux"], h)
    h = layernorm(h, params["head"]["ln_g"], params["head"]["ln_b"])
    hd = model.meta["head"]
    if hd == "lm":
        return linear(h, params["head"]["out_w"], params["head"]["out_b"]), stats
    if hd == "cls":
        pooled = h.mean(axis=1)
        return linear(pooled, params["head"]["out_w"], params["head"]["out_b"]), stats
    if hd == "span":
        s_log = (h @ params["head"]["start_w"].T)[..., 0]
        e_log = (h @ params["head"]["end_w"].T)[..., 0]
        return (s_log, e_log), stats
    if hd == "multi":
        if task == "span":
            s_log = (h @ params["head"]["span_start_w"].T)[..., 0]
            e_log = (h @ params["head"]["span_end_w"].T)[..., 0]
            return (s_log, e_log), stats
        pooled = h.mean(axis=1)
        return linear(pooled, params["head"][f"{task}_w"],
                      params["head"][f"{task}_b"]), stats
    raise ValueError(hd)


def _apply_cnn_unit_train(u: QUnit, up, x, train, stats):
    """Train-time CNN unit: conv → BN → activation per layer, following the
    same topology `apply_unit` uses post-folding."""
    def cb(name, xin, stride=1, groups=1, act=None):
        l = next(l for l in u.layers if l.name == name)
        p = up["layers"][name]
        y = conv2d(xin, p["w"], p["b"], stride, groups)
        y, ms = _bn_apply(y, up["bn"][name], train)
        stats[(u.name, name)] = ms
        if act == "relu":
            y = relu(y)
        elif act == "relu6":
            y = relu6(y)
        return y

    if u.kind == "stem_conv":
        l0 = u.layers[0]
        return cb("conv", x, l0.stride, act="relu6" if l0.relu6 else "relu")
    if u.kind == "res_block":
        y = cb("conv1", x, u.layers[0].stride, act="relu")
        y = cb("conv2", y)
        sc = cb("proj", x, u.layers[2].stride) if len(u.layers) == 3 else x
        return relu(y + sc)
    if u.kind == "bottleneck_block":
        y = cb("conv1", x, act="relu")
        y = cb("conv2", y, u.layers[1].stride, act="relu")
        y = cb("conv3", y)
        sc = cb("proj", x, u.layers[3].stride) if len(u.layers) == 4 else x
        return relu(y + sc)
    if u.kind == "invres_block":
        y = cb("expand", x, act="relu6")
        y = cb("dw", y, u.layers[1].stride, groups=y.shape[-1], act="relu6")
        y = cb("project", y)
        return y + x if u.meta.get("skip") else y
    if u.kind == "head_conv":
        return cb("conv", x, act="relu6")
    raise ValueError(u.kind)


def fold_bn(model: QModel, params):
    """Fold BN into conv weights/biases: w' = w·γ/√(σ²+ε), b' = (b−μ)·γ/√(σ²+ε)+β.
    Returns a new params pytree with bn removed — the exported QModel weights."""
    if model.kind != "cnn":
        return params
    out = jax.tree_util.tree_map(lambda a: a, params)
    eps = 1e-5
    for u in model.units:
        if u.kind == "head_fc":
            continue
        up = out["units"][u.name]
        for l in u.layers:
            p = up["layers"][l.name]
            bn = up["bn"][l.name]
            scale = bn["g"] / jnp.sqrt(bn["var"] + eps)
            p["w"] = p["w"] * scale[None, None, None, :]
            p["b"] = (p["b"] - bn["mean"]) * scale + bn["b"]
        up["bn"] = {}
    return out


# ---------------------------------------------------------------------------
# LoRA (Hu et al., 2022) — low-rank adapters merged into the base weights
# before PTQ, matching the paper's GPT-2 + LoRA pipeline (Table 6).
# ---------------------------------------------------------------------------

LORA_RANK = 4
LORA_ALPHA = 8.0
LORA_TARGETS = ("wq", "wv")   # paper Appendix L: query and value projections


def lora_init(model: QModel, seed: int):
    rng = np.random.default_rng(seed)
    adapters = {}
    for u in model.units:
        if u.kind != "txl":
            continue
        for l in u.layers:
            if l.name in LORA_TARGETS:
                dout, din = l.wshape
                adapters[(u.name, l.name)] = {
                    "a": jnp.asarray(rng.normal(0, 0.02, (LORA_RANK, din)).astype(np.float32)),
                    "b": jnp.zeros((dout, LORA_RANK), np.float32),
                }
    return adapters


def lora_apply_w(w, ad):
    """Effective weight with the adapter: W + (α/r)·B·A."""
    return w + (LORA_ALPHA / LORA_RANK) * (ad["b"] @ ad["a"])


def lora_merge(model: QModel, params, adapters):
    """Merge adapters into the base weights (the checkpoint PTQ sees)."""
    out = jax.tree_util.tree_map(lambda a: a, params)
    for (uname, lname), ad in adapters.items():
        p = out["units"][uname]["layers"][lname]
        p["w"] = lora_apply_w(p["w"], ad)
    return out


def forward_lora(model: QModel, params, adapters, x):
    """Training-time forward with unmerged adapters (only adapters get grads)."""
    emb = params["pre"]["tok"][x] + params["pre"]["pos"][None, : x.shape[1]]
    h = emb
    for u in model.units:
        up = params["units"][u.name]
        ws = []
        for l in u.layers:
            w = up["layers"][l.name]["w"]
            ad = adapters.get((u.name, l.name))
            if ad is not None:
                w = lora_apply_w(jax.lax.stop_gradient(w), ad)
            else:
                w = jax.lax.stop_gradient(w)
            ws.append(w)
        bs = [jax.lax.stop_gradient(up["layers"][l.name]["b"]) for l in u.layers]
        aux = [jax.lax.stop_gradient(a) for a in up["aux"]]
        h = apply_unit(u, ws, bs, aux, h)
    h = layernorm(h, jax.lax.stop_gradient(params["head"]["ln_g"]),
                  jax.lax.stop_gradient(params["head"]["ln_b"]))
    return linear(h, jax.lax.stop_gradient(params["head"]["out_w"]),
                  jax.lax.stop_gradient(params["head"]["out_b"]))
