"""Layer-2 quantization ops: custom-VJP fake-quant built on the Pallas kernels.

This module is the bridge between the L1 kernels (`compile.kernels.*`) and
the reconstruction graphs (`compile.graphs`).  Every fake-quant op is a
`jax.custom_vjp` whose forward is the fused Pallas kernel and whose backward
implements the straight-through estimator with the closed-form cotangents of
Proposition 3.1 — the element-wise heavy lifting also runs through a Pallas
kernel, and only the O(r+c) reductions are left to XLA.

Canonical parameter layout (2D view, rows = C_out):

    w  : (r, c)     s1 : (r, 1)     S2 : (r, c)
    s3 : (r, 1)     s4 : (1, c)     zp : (r, 1)

Per-*tensor* s1 is represented by a scalar in the parameter pytree and
broadcast to (r, 1) before the op; JAX's broadcast transpose then reduces the
(r, 1) cotangent back to the scalar automatically.  Ablations (fixed s1 /
missing s3, s4) pass `stop_gradient`-wrapped or constant-one factors.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from compile.kernels import baselines as kb
from compile.kernels import flexround as kf
from compile.kernels import ref


# ---------------------------------------------------------------------------
# FlexRound fake-quant op
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fq_flexround(w, s1, s2, s3, s4, zp, qmin, qmax):
    """Ŵ = s1 · (clip(round(W/(s1⊙S2⊙s3⊙s4)) + z, qmin, qmax) − z)."""
    return kf.flexround_fq(w, s1, s2, s3, s4, zp, qmin, qmax)


def _fq_flexround_fwd(w, s1, s2, s3, s4, zp, qmin, qmax):
    out = kf.flexround_fq(w, s1, s2, s3, s4, zp, qmin, qmax)
    return out, (w, s1, s2, s3, s4, zp, qmin, qmax)


def _fq_flexround_bwd(res, g):
    w, s1, s2, s3, s4, zp, qmin, qmax = res
    ds1_full, common = kf.flexround_fq_bwd(w, s1, s2, s3, s4, zp, g, qmin, qmax)
    ds1 = jnp.sum(ds1_full, axis=1, keepdims=True)
    ds2 = common / s2
    ds3 = jnp.sum(common / s3, axis=1, keepdims=True)
    ds4 = jnp.sum(common / s4, axis=0, keepdims=True)
    # dŴ/dW through STE: g · inside / (S2⊙s3⊙s4).  `common` already carries
    # g·s1·inside·(−W/(s1⊙S')), so inside·g = −common·S'/W is ill-posed at
    # W=0; recompute the mask directly instead (cheap, fuses).
    div = s1 * s2 * s3 * s4
    n = jnp.round(w / div) + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    dw = g * inside / (s2 * s3 * s4)
    dzp = jnp.sum(g * (s1 * inside - s1), axis=1, keepdims=True)
    zs = jnp.zeros((), w.dtype)
    return dw, ds1, ds2, ds3, ds4, dzp, zs, zs


fq_flexround.defvjp(_fq_flexround_fwd, _fq_flexround_bwd)


# ---------------------------------------------------------------------------
# AdaRound fake-quant op (fixed s1, learnable V)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fq_adaround(w, s1, v, zp, qmin, qmax):
    return kb.adaround(w, s1, v, zp, qmin, qmax)


def _fq_adaround_fwd(w, s1, v, zp, qmin, qmax):
    return kb.adaround(w, s1, v, zp, qmin, qmax), (w, s1, v, zp, qmin, qmax)


def _fq_adaround_bwd(res, g):
    w, s1, v, zp, qmin, qmax = res
    dv = kb.adaround_bwd(w, s1, v, zp, g, qmin, qmax)
    zero = jnp.zeros_like
    zs = jnp.zeros((), w.dtype)
    return zero(w), zero(s1), dv, zero(zp), zs, zs


fq_adaround.defvjp(_fq_adaround_fwd, _fq_adaround_bwd)


# ---------------------------------------------------------------------------
# AdaQuant fake-quant op (learnable s1 and V)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fq_adaquant(w, s1, v, zp, qmin, qmax):
    return kb.adaquant(w, s1, v, zp, qmin, qmax)


def _fq_adaquant_fwd(w, s1, v, zp, qmin, qmax):
    return kb.adaquant(w, s1, v, zp, qmin, qmax), (w, s1, v, zp, qmin, qmax)


def _fq_adaquant_bwd(res, g):
    w, s1, v, zp, qmin, qmax = res
    dv, ds1_full = kb.adaquant_bwd(w, s1, v, zp, g, qmin, qmax)
    ds1 = jnp.sum(ds1_full, axis=1, keepdims=True)
    zs = jnp.zeros((), w.dtype)
    return jnp.zeros_like(w), ds1, dv, jnp.zeros_like(zp), zs, zs


fq_adaquant.defvjp(_fq_adaquant_fwd, _fq_adaquant_bwd)


# ---------------------------------------------------------------------------
# AdaQuant ⊕ FlexRound (Appendix F) — jnp backward (appendix-only path)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fq_adaquant_flexround(w, s1, v, s2, s3, s4, zp, qmin, qmax):
    return kb.adaquant_flexround(w, s1, v, s2, s3, s4, zp, qmin, qmax)


def _fq_aqfr_fwd(w, s1, v, s2, s3, s4, zp, qmin, qmax):
    out = kb.adaquant_flexround(w, s1, v, s2, s3, s4, zp, qmin, qmax)
    return out, (w, s1, v, s2, s3, s4, zp, qmin, qmax)


def _fq_aqfr_bwd(res, g):
    w, s1, v, s2, s3, s4, zp, qmin, qmax = res
    wv = w + v
    div = s1 * s2 * s3 * s4
    r_ = wv / div
    n = jnp.round(r_) + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    common = g * s1 * inside * (-r_)
    ds1 = jnp.sum(g * ((n_c - zp) - inside * r_), axis=1, keepdims=True)
    dv = g * inside / (s2 * s3 * s4)
    ds2 = common / s2
    ds3 = jnp.sum(common / s3, axis=1, keepdims=True)
    ds4 = jnp.sum(common / s4, axis=0, keepdims=True)
    zs = jnp.zeros((), w.dtype)
    return jnp.zeros_like(w), ds1, dv, ds2, ds3, ds4, jnp.zeros_like(zp), zs, zs


fq_adaquant_flexround.defvjp(_fq_aqfr_fwd, _fq_aqfr_bwd)


# ---------------------------------------------------------------------------
# LSQ activation fake-quant op
# ---------------------------------------------------------------------------

@jax.custom_vjp
def fq_lsq_act(x2d, step, zp, qmin, qmax):
    """Per-tensor activation fake-quant; step/zp are (1,1)."""
    return kb.lsq_act(x2d, step, zp, qmin, qmax)


def _fq_lsq_fwd(x2d, step, zp, qmin, qmax):
    return kb.lsq_act(x2d, step, zp, qmin, qmax), (x2d, step, zp, qmin, qmax)


def _fq_lsq_bwd(res, g):
    x2d, step, zp, qmin, qmax = res
    dx, dstep_full = kb.lsq_act_bwd(x2d, step, zp, g, qmin, qmax)
    gscale = ref.lsq_grad_scale(x2d, qmax)
    dstep = jnp.sum(dstep_full).reshape(1, 1) * gscale
    zs = jnp.zeros((), x2d.dtype)
    return dx, dstep, jnp.zeros_like(zp), zs, zs


fq_lsq_act.defvjp(_fq_lsq_fwd, _fq_lsq_bwd)


def quant_act(x, step, zp, qmin, qmax):
    """Fake-quant an activation tensor of any rank (flatten → kernel → restore)."""
    shp = x.shape
    x2d = x.reshape(-1, shp[-1])
    out = fq_lsq_act(x2d, step, zp, qmin, qmax)
    return out.reshape(shp)


def qdrop(x_fp, x_q, key, p: float):
    """QDrop: keep the *quantized* activation with prob (1−p); replace by the
    full-precision value with prob p (paper uses p = 0.5)."""
    keep = jax.random.bernoulli(key, 1.0 - p, shape=x_q.shape)
    return jnp.where(keep, x_q, x_fp)


# ---------------------------------------------------------------------------
# Parameter initialization per method
# ---------------------------------------------------------------------------

METHODS = (
    "rtn",
    "adaround",
    "adaquant",
    "flexround",
    "flexround_fixed_s1",   # Ablation Study 1 (Table 1)
    "flexround_no_s34",     # Ablation Study 2 (Table 1)
    "adaquant_flexround",   # Appendix F combo (Table 11)
)

LEARNABLE_METHODS = tuple(m for m in METHODS if m != "rtn")


def conv_to_2d(w):
    """(Kh, Kw, Cin, Cout) HWIO conv weight → canonical (Cout, Kh·Kw·Cin)."""
    kh, kw, cin, cout = w.shape
    return jnp.transpose(w, (3, 0, 1, 2)).reshape(cout, kh * kw * cin)


def conv_from_2d(w2d, conv_shape):
    kh, kw, cin, cout = conv_shape
    return jnp.transpose(w2d.reshape(cout, kh, kw, cin), (1, 2, 3, 0))


def conv_s4_cols(s4_cin, kh, kw):
    """Expand a per-input-channel (1, Cin) scale to the flattened column
    layout (1, Kh·Kw·Cin) of `conv_to_2d` (channel index is fastest)."""
    return jnp.tile(s4_cin, (1, kh * kw))


def init_params(method: str, w2d, bits: int, symmetric: bool,
                per_channel: bool, conv_cin: Optional[int] = None,
                ksize: int = 1) -> Dict[str, jnp.ndarray]:
    """Initial learnable-parameter pytree for `method` on weights `w2d`.

    `conv_cin`/`ksize` describe the conv column structure for s4 (ksize =
    Kh·Kw); linear layers leave them None/1 so s4 degenerates to ones(1, c).
    Every method starts exactly at rounding-to-nearest (S2 = s3 = s4 = 1,
    V s.t. h(V) = frac, additive V = 0) — the paper's §3.2 init.
    """
    r, c = w2d.shape
    s1, zp = ref.minmax_scale(w2d, bits, symmetric, per_channel)
    s1b = jnp.broadcast_to(jnp.reshape(s1, (-1, 1)), (r, 1)).astype(w2d.dtype)
    zpb = jnp.broadcast_to(jnp.reshape(zp, (-1, 1)), (r, 1)).astype(w2d.dtype)
    p: Dict[str, jnp.ndarray] = {"zp": zpb}
    if per_channel:
        p["s1"] = s1b
    else:
        p["s1"] = jnp.reshape(s1, (1, 1)).astype(w2d.dtype)
        p["zp"] = jnp.reshape(zp, (1, 1)).astype(w2d.dtype)

    if method in ("flexround", "flexround_fixed_s1", "flexround_no_s34",
                  "adaquant_flexround"):
        p["s2"] = jnp.ones((r, c), w2d.dtype)
        p["s3"] = jnp.ones((r, 1), w2d.dtype)
        p["s4"] = jnp.ones((1, c), w2d.dtype)
    if method in ("adaround",):
        p["v"] = ref.adaround_init_v(w2d, _bcast_rows(p["s1"], r)).astype(w2d.dtype)
    if method in ("adaquant", "adaquant_flexround"):
        p["v"] = jnp.zeros((r, c), w2d.dtype)
    return p


def _bcast_rows(x, r):
    """(1,1) or (r,1) → (r,1)."""
    return jnp.broadcast_to(x, (r, 1))


def learnable_keys(method: str):
    """Which parameter-pytree entries receive gradient updates."""
    return {
        "rtn": (),
        "adaround": ("v",),
        "adaquant": ("s1", "v"),
        "flexround": ("s1", "s2", "s3", "s4"),
        "flexround_fixed_s1": ("s2", "s3", "s4"),
        "flexround_no_s34": ("s1", "s2"),
        "adaquant_flexround": ("s1", "v", "s2", "s3", "s4"),
    }[method]


def fake_quant(method: str, w2d, p: Dict[str, jnp.ndarray], qmin: int, qmax: int,
               impl: str = "pallas"):
    """Dispatch: fake-quantize `w2d` with `method`'s parameters `p`.

    Gradient flow is shaped here: ablation variants stop the gradient on the
    frozen factors rather than using separate kernels.

    `impl="jnp"` routes through the pure-jnp oracles instead of the Pallas
    kernels — numerically identical (pinned by pytest), used for the
    *forward-only* q/qw artifacts where tracing the Pallas interpreter buys
    nothing and costs AOT build time.  Reconstruction always uses Pallas.
    """
    if impl == "jnp":
        return _fake_quant_ref(method, w2d, p, qmin, qmax)
    r, c = w2d.shape
    qmin = jnp.asarray(qmin, w2d.dtype)
    qmax = jnp.asarray(qmax, w2d.dtype)
    s1 = _bcast_rows(p["s1"], r)
    zp = _bcast_rows(p["zp"], r)
    zp = jax.lax.stop_gradient(zp)
    if method == "rtn":
        return kb.rtn(w2d, jax.lax.stop_gradient(s1), zp, qmin, qmax)
    if method == "adaround":
        return fq_adaround(w2d, jax.lax.stop_gradient(s1), p["v"], zp, qmin, qmax)
    if method == "adaquant":
        return fq_adaquant(w2d, s1, p["v"], zp, qmin, qmax)
    if method == "flexround":
        return fq_flexround(w2d, s1, p["s2"], p["s3"], p["s4"], zp, qmin, qmax)
    if method == "flexround_fixed_s1":
        return fq_flexround(
            w2d, jax.lax.stop_gradient(s1), p["s2"], p["s3"], p["s4"], zp, qmin, qmax
        )
    if method == "flexround_no_s34":
        ones_r = jax.lax.stop_gradient(jnp.ones((r, 1), w2d.dtype))
        ones_c = jax.lax.stop_gradient(jnp.ones((1, c), w2d.dtype))
        return fq_flexround(w2d, s1, p["s2"], ones_r, ones_c, zp, qmin, qmax)
    if method == "adaquant_flexround":
        return fq_adaquant_flexround(
            w2d, s1, p["v"], p["s2"], p["s3"], p["s4"], zp, qmin, qmax
        )
    raise ValueError(f"unknown method {method!r}")


def _fake_quant_ref(method: str, w2d, p: Dict[str, jnp.ndarray], qmin, qmax):
    """Pure-jnp forward dispatch (oracle path; no custom VJP, no Pallas)."""
    r, c = w2d.shape
    s1 = _bcast_rows(p["s1"], r)
    zp = _bcast_rows(p["zp"], r)
    if method == "rtn":
        return ref.rtn(w2d, s1, qmin, qmax, zp)
    if method == "adaround":
        return ref.adaround(w2d, s1, p["v"], qmin, qmax, zp)
    if method == "adaquant":
        return ref.adaquant(w2d, s1, p["v"], qmin, qmax, zp)
    if method in ("flexround", "flexround_fixed_s1"):
        return ref.flexround(w2d, s1, p["s2"], p["s3"], p["s4"], qmin, qmax, zp)
    if method == "flexround_no_s34":
        return ref.flexround(w2d, s1, p["s2"], None, None, qmin, qmax, zp)
    if method == "adaquant_flexround":
        return ref.adaquant_flexround(w2d, s1, p["v"], p["s2"], p["s3"], p["s4"],
                                      qmin, qmax, zp)
    raise ValueError(f"unknown method {method!r}")


def fake_quant_matmul(method: str, x, w2d, p, qmin: int, qmax: int):
    """Fused Ŷ = X̃·Ŵᵀ when the method supports the fused kernel (FlexRound
    forward path); falls back to fake_quant + matmul otherwise.  Backward
    still flows through the custom-VJP op (the fused kernel is a forward
    optimization; gradients only exist during reconstruction where we use
    the unfused op so parameter cotangents are exact)."""
    if method == "flexround":
        r, _ = w2d.shape
        s1 = _bcast_rows(p["s1"], r)
        zp = _bcast_rows(p["zp"], r)
        return kf.flexround_matmul(x, w2d, s1, p["s2"], p["s3"], p["s4"], zp, qmin, qmax)
    return x @ fake_quant(method, w2d, p, qmin, qmax).T


def quant_int_codes(method: str, w2d, p, qmin: int, qmax: int, impl: str = "jnp"):
    """Integer grid codes after learning — consumed by the Rust grid-shift
    analysis (Figures 3–6)."""
    r, c = w2d.shape
    s1 = _bcast_rows(p["s1"], r)
    zp = _bcast_rows(p["zp"], r)
    if method == "rtn":
        return ref.rtn_int(w2d, s1, qmin, qmax, zp)
    if method == "adaround":
        h = ref.adaround_h(p["v"])
        h = (h >= 0.5).astype(w2d.dtype)
        return jnp.clip(jnp.floor(w2d / s1) + h + zp, qmin, qmax)
    if method == "adaquant":
        return jnp.clip(jnp.round((w2d + p["v"]) / s1) + zp, qmin, qmax)
    if method in ("flexround", "flexround_fixed_s1"):
        if impl == "jnp":
            return ref.flexround_int(w2d, s1, p["s2"], p["s3"], p["s4"], qmin, qmax, zp)
        return kf.flexround_fq_int(w2d, s1, p["s2"], p["s3"], p["s4"], zp, qmin, qmax)
    if method == "flexround_no_s34":
        if impl == "jnp":
            return ref.flexround_int(w2d, s1, p["s2"], None, None, qmin, qmax, zp)
        ones_r = jnp.ones((r, 1), w2d.dtype)
        ones_c = jnp.ones((1, w2d.shape[1]), w2d.dtype)
        return kf.flexround_fq_int(w2d, s1, p["s2"], ones_r, ones_c, zp, qmin, qmax)
    if method == "adaquant_flexround":
        div = p["s1"] * p["s2"] * p["s3"] * p["s4"]
        return jnp.clip(jnp.round((w2d + p["v"]) / div) + zp, qmin, qmax)
    raise ValueError(f"unknown method {method!r}")


# ---------------------------------------------------------------------------
# In-graph Adam — optimizer state round-trips through PJRT buffers so the
# whole reconstruction step is one executable.
# ---------------------------------------------------------------------------

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params)}


def adam_update(params, grads, state, t, lr):
    """One Adam step; `t` is the 1-based iteration count as an f32 scalar."""
    b1t = 1.0 - ADAM_B1**t
    b2t = 1.0 - ADAM_B2**t

    def upd(p, g, m, v):
        m2 = ADAM_B1 * m + (1.0 - ADAM_B1) * g
        v2 = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
        mhat = m2 / b1t
        vhat = v2 / b2t
        return p - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state["m"])
    flat_v = jax.tree_util.tree_leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v}


def clamp_positive(params, keys=("s1", "s2", "s3", "s4")):
    """Enforce the paper's positivity constraint after each update."""
    out = dict(params)
    for k in keys:
        if k in out:
            out[k] = jnp.maximum(out[k], 1e-6)
    return out
