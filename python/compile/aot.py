"""AOT build: lower every Layer-2 graph to HLO text and export weights,
datasets, and init packs for the Rust coordinator.

This is the ONLY Python entry point of the system (`make artifacts`); after
it finishes, the Rust binary is self-contained.  Interchange formats:

  *.hlo.txt        — HLO text (NOT serialized protos: the image's
                     xla_extension 0.5.1 rejects jax≥0.5's 64-bit ids; the
                     text parser reassigns them — see /opt/xla-example).
  *.fxt            — named tensors (compile/fxt.py ⇄ rust/src/ser/fxt.rs).
  manifest.json    — the complete system description: models, units, layer
                     shapes, artifact names + signatures, parameter-pack
                     orderings, method/bit matrices, default hyperparams.

Artifact families per model (see compile/graphs.py):
  embed, fp_<unit>, recon_<unit>_<method>_<mode>, q_<unit>_<method>_<mode>,
  qw_<unit>_<method>, head[_<task>], head_logits.

Incremental: existing .hlo.txt files are kept unless --force; checkpoints
cache under artifacts/ckpt/.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from compile import cle as C
from compile import data as D
from compile import fxt
from compile import graphs as G
from compile import models as M
from compile import quant as Q
from compile import train as T

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
CAL_B = 32           # fixed batch of every unit-level executable

F32 = jnp.float32
I32 = jnp.int32


# ---------------------------------------------------------------------------
# Model configuration matrix (which methods/modes/bits each model ships)
# ---------------------------------------------------------------------------

FULL_W = ["rtn", "adaround", "adaquant", "flexround",
          "flexround_fixed_s1", "flexround_no_s34", "adaquant_flexround"]
ALT_W = ["rtn", "adaround", "flexround"]
RED_W = ["rtn", "adaround", "adaquant", "flexround"]
WA2 = ["adaround", "flexround"]

MODEL_CFG = {
    # ImageNet analogs — linear symmetric per-tensor (paper §4.2)
    "tinyresnet_a":      dict(kind="cnn", methods_w=FULL_W, methods_wa=WA2,
                              bits_w=[2, 3, 4, 8], abits=[3, 4, 8],
                              symmetric=True, per_channel=False, calib_n=1024),
    "tinyresnet_b":      dict(kind="cnn", methods_w=RED_W, methods_wa=WA2,
                              bits_w=[2, 3, 4, 8], abits=[3, 4, 8],
                              symmetric=True, per_channel=False, calib_n=1024),
    "tinymobilenet":     dict(kind="cnn", methods_w=FULL_W, methods_wa=WA2,
                              bits_w=[2, 3, 4, 8], abits=[3, 4, 8],
                              symmetric=True, per_channel=False, calib_n=1024),
    # Tables 8/9: alternate checkpoints
    "tinyresnet_a_alt":  dict(kind="cnn", methods_w=ALT_W, methods_wa=WA2,
                              bits_w=[2, 3, 4, 8], abits=[3, 4, 8],
                              symmetric=True, per_channel=False, calib_n=1024),
    "tinymobilenet_alt": dict(kind="cnn", methods_w=ALT_W, methods_wa=WA2,
                              bits_w=[2, 3, 4, 8], abits=[3, 4, 8],
                              symmetric=True, per_channel=False, calib_n=1024),
    # Table 10: CLE + AHB preprocessed MobileNets (weight-only)
    "tinymobilenet_cle":     dict(kind="cnn", base="tinymobilenet", cle=True,
                                  methods_w=WA2 + ["rtn"], methods_wa=[],
                                  bits_w=[4, 8], abits=[8],
                                  symmetric=True, per_channel=False, calib_n=1024),
    # GLUE analogs — per-tensor asymmetric 8/8 (paper §4.3)
    "enc_small":  dict(kind="encoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                       bits_w=[8], abits=[8], symmetric=False,
                       per_channel=False, calib_n=256),
    "enc_base":   dict(kind="encoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                       bits_w=[8], abits=[8], symmetric=False,
                       per_channel=False, calib_n=256),
    # NLG analogs — per-tensor asymmetric 8/8, 128 calib samples (App. I)
    "dec_small_lma": dict(kind="decoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                          bits_w=[8], abits=[8], symmetric=False,
                          per_channel=False, calib_n=128),
    "dec_small_lmb": dict(kind="decoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                          bits_w=[8], abits=[8], symmetric=False,
                          per_channel=False, calib_n=128),
    "dec_med_lma":   dict(kind="decoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                          bits_w=[8], abits=[8], symmetric=False,
                          per_channel=False, calib_n=128),
    "dec_med_lmb":   dict(kind="decoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                          bits_w=[8], abits=[8], symmetric=False,
                          per_channel=False, calib_n=128),
    # Table 6: LoRA-merged GPT-2 analog
    "dec_lora":   dict(kind="decoder", methods_w=[], methods_wa=WA2 + ["rtn"],
                       bits_w=[8], abits=[8], symmetric=False,
                       per_channel=False, calib_n=128),
    # LLaMA analog — per-channel asymmetric weights, per-tensor activations
    "llm_mini":   dict(kind="decoder", methods_w=WA2 + ["rtn"],
                       methods_wa=WA2 + ["rtn"],
                       bits_w=[3, 4, 8], abits=[8], symmetric=False,
                       per_channel=True, calib_n=512),
}

# Reconstruction hyperparameter defaults (overridable from Rust configs);
# per-method learning rates echo the paper's observation that AdaRound's
# sigmoid-space V needs larger steps than FlexRound's scales.
HYPER = {
    "iters": {"cnn": 350, "encoder": 250, "decoder": 250},
    "lr": {"adaround": 1e-2, "adaquant": 1e-3, "flexround": 2e-3,
           "flexround_fixed_s1": 2e-3, "flexround_no_s34": 2e-3,
           "adaquant_flexround": 1e-3},
    "drop_p": 0.5,
}


# ---------------------------------------------------------------------------
# Lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(fn, specs, return_tuple: bool = True) -> str:
    """Lower to HLO text.  `return_tuple=False` for single-output graphs so
    the PJRT output buffer is the bare array — the Rust runtime then chains
    unit executables on-device via execute_b without host round-trips."""
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=return_tuple
    )
    # CRITICAL: the default printer elides large constants as "{...}" — the
    # baked weights would silently vanish and the 0.5.1 text parser accepts
    # the placeholder. print_large_constants keeps them verbatim.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # modern metadata attrs (source_end_line, …) break the 0.5.1 text parser
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


class Emitter:
    def __init__(self, outdir: str, force: bool):
        self.outdir = outdir
        self.force = force
        self.count = 0
        self.skipped = 0

    def emit(self, name: str, fn, specs, return_tuple: bool = True) -> str:
        fname = f"{name}.hlo.txt"
        path = os.path.join(self.outdir, fname)
        if os.path.exists(path) and not self.force:
            self.skipped += 1
            return fname
        text = to_hlo_text(fn, specs, return_tuple)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)  # atomic: the Rust runtime never sees partials
        self.count += 1
        if self.count % 25 == 0:
            print(f"    …{self.count} artifacts lowered")
        return fname


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


SCALARS_RECON = 8   # qmin_w qmax_w qmin_a qmax_a drop_p beta lr t (f32), then seed i32
SCALARS_Q = 4       # qmin_w qmax_w qmin_a qmax_a


# ---------------------------------------------------------------------------
# Per-model build
# ---------------------------------------------------------------------------

def build_model(name: str, cfg: dict, em: Emitter, outdir: str):
    t0 = time.time()
    base = cfg.get("base", name)
    model, params, info = T.load_or_train(base)
    if cfg.get("cle"):
        model.name = name
        model, params = C.preprocess(model, params)
        info = dict(info)
        info["preprocessing"] = "relu6_to_relu+cle+ahb"
    print(f"  [{name}] building artifacts (fp metric: {info.get('fp_metric')})")

    entry = {
        "kind": cfg["kind"], "task": info.get("task"),
        "fp_metric": info.get("fp_metric"), "info": {
            k: v for k, v in info.items() if k not in ("task", "fp_metric")},
        "symmetric": cfg["symmetric"], "per_channel": cfg["per_channel"],
        "bits_w": cfg["bits_w"], "abits": cfg["abits"],
        "methods_w": cfg["methods_w"], "methods_wa": cfg["methods_wa"],
        "calib_n": cfg["calib_n"], "calib_batch": CAL_B,
        "hyper": {"iters": HYPER["iters"][cfg["kind"]], "lr": HYPER["lr"],
                  "drop_p": HYPER["drop_p"]},
    }
    if cfg["kind"] != "cnn":
        entry["seq"] = model.meta["seq"]
        entry["vocab"] = model.meta["vocab"]

    # ---- datasets -----------------------------------------------------
    datasets = make_datasets(name, cfg, info)
    data_file = f"{name}.data.fxt"
    fxt.write(os.path.join(outdir, data_file), datasets)
    entry["data_file"] = data_file
    entry["datasets"] = {k: list(v.shape) for k, v in datasets.items()}

    # ---- chain shapes + activation ranges -----------------------------
    calib = jnp.asarray(datasets["calib_x"][:CAL_B])
    if cfg["kind"] != "cnn":
        emb = G.embed_fn(model, params)
        x = emb(calib)
        entry["embed_artifact"] = em.emit(
            f"{name}.embed", lambda t: emb(t), [spec(calib.shape, I32)],
            return_tuple=False)
    else:
        x = calib

    units_meta = []
    weights = {}
    inits = {}
    for u in model.units:
        views = G.layer_views(model, params, u)
        fp = G.fp_unit_fwd(model, params, u)
        y = fp(x)
        act_ranges = G.calibrate_act_ranges(model, params, u, x)
        um = {
            "name": u.name, "kind": u.kind,
            "bits_override": u.bits_override,
            "in_shape": list(x.shape[1:]), "out_shape": list(y.shape[1:]),
            "act_sites": G.n_act_sites(u),
            "layers": [{
                "name": v.name, "kind": v.kind, "rows": v.rc[0], "cols": v.rc[1],
                "conv_shape": list(v.conv_shape) if v.conv_shape else None,
                "stride": v.stride,
            } for v in views],
            "artifacts": {}, "packs": {},
        }

        # weights + act ranges
        for v in views:
            weights[f"w/{u.name}/{v.name}"] = np.asarray(v.w2d)
            weights[f"b/{u.name}/{v.name}"] = np.asarray(v.bias)
        if u.kind == "txl":
            for i, a in enumerate(params["units"][u.name]["aux"]):
                weights[f"aux/{u.name}/{i}"] = np.asarray(a)
        for i, (lo, hi) in enumerate(act_ranges):
            inits[f"actrange/{u.name}/site{i}"] = np.array([lo, hi], np.float32)

        # fp artifact
        um["artifacts"]["fp"] = em.emit(
            f"{name}.fp.{u.name}", lambda t, _fp=fp: _fp(t), [spec(x.shape)],
            return_tuple=False)

        # quantized-path artifacts per (method, mode)
        combos = [(m, "w") for m in cfg["methods_w"]] + \
                 [(m, "wa") for m in cfg["methods_wa"]]
        for method, mode in combos:
            pack = G.ParamPack.build(method, views, mode, G.n_act_sites(u),
                                     cfg["per_channel"])
            um["packs"][f"{method}.{mode}"] = [
                {"name": e.name, "shape": list(e.shape), "learnable": e.learnable}
                for e in pack.entries]
            pspecs = [spec(e.shape) for e in pack.entries]
            # forward-only executable → jnp oracle path (fast AOT); the recon
            # executable below keeps the Pallas hot path.
            fwd = G.quantized_unit_fwd(model, params, u, method, mode, pack,
                                       views, impl="jnp", use_qdrop=False)

            def q_fn(t, qmin_w, qmax_w, qmin_a, qmax_a, *flat, _fwd=fwd):
                key = jax.random.PRNGKey(0)
                return _fwd(list(flat), t, qmin_w, qmax_w, qmin_a, qmax_a,
                            jnp.float32(0.0), key)

            um["artifacts"][f"q.{method}.{mode}"] = em.emit(
                f"{name}.q.{u.name}.{method}.{mode}", q_fn,
                [spec(x.shape)] + [spec(()) for _ in range(SCALARS_Q)] + pspecs,
                return_tuple=False)

            if method != "rtn":
                step = G.recon_step_fn(model, params, u, method, mode, pack, views)
                um["artifacts"][f"recon.{method}.{mode}"] = em.emit(
                    f"{name}.recon.{u.name}.{method}.{mode}", step,
                    [spec(x.shape), spec(y.shape)]
                    + [spec(()) for _ in range(SCALARS_RECON)]
                    + [spec((), I32)] + pspecs * 3)

            # init packs per bit-width (weight entries only; act init derives
            # from actrange at runtime)
            for bits in cfg["bits_w"]:
                vals = pack.init_values(method, views, bits, cfg["symmetric"],
                                        cfg["per_channel"],
                                        act_init=act_ranges, abits=8)
                for e, val in zip(pack.entries, vals):
                    if e.name.startswith("act"):
                        continue
                    inits[f"init/{u.name}/{method}/b{bits}/{e.name}"] = val

        # qw export per method (mode-independent; use the "w" pack)
        for method in dict.fromkeys(cfg["methods_w"] + cfg["methods_wa"]):
            pack = G.ParamPack.build(method, views, "w", 0, cfg["per_channel"])
            exp = G.qw_export_fn(views, method, pack)
            um["artifacts"][f"qw.{method}"] = em.emit(
                f"{name}.qw.{u.name}.{method}", exp,
                [spec(()), spec(())] + [spec(e.shape) for e in pack.entries])

        units_meta.append(um)
        x = y

    entry["units"] = units_meta

    # ---- heads ----------------------------------------------------------
    if cfg["kind"] != "cnn":
        entry["head_artifacts"] = {}
        if model.meta["head"] == "lm":
            hf = G.head_fn(model, params)
            entry["head_artifacts"]["lm"] = em.emit(
                f"{name}.head.lm", lambda h, t: hf(h, t),
                [spec(x.shape), spec((CAL_B, model.meta["seq"]), I32)])
            # logits head for greedy generation (BLEU / Table 6)
            lng, lnb = params["head"]["ln_g"], params["head"]["ln_b"]
            ow, ob = params["head"]["out_w"], params["head"]["out_b"]

            def logits_fn(h):
                hn = M.layernorm(h, lng, lnb)
                return M.linear(hn, ow, ob)

            entry["head_artifacts"]["logits"] = em.emit(
                f"{name}.head.logits", logits_fn, [spec(x.shape)],
                return_tuple=False)
        else:
            for task in list(D.NLU_TASKS):
                hf = G.head_fn(model, params, task)
                entry["head_artifacts"][task] = em.emit(
                    f"{name}.head.{task}", lambda h, _hf=hf: _hf(h),
                    [spec(x.shape)], return_tuple=False)
            hf = G.head_fn(model, params, "span")
            entry["head_artifacts"]["span"] = em.emit(
                f"{name}.head.span", lambda h, _hf=hf: _hf(h), [spec(x.shape)])

    # ---- weight + init files -------------------------------------------
    if cfg["kind"] != "cnn":
        weights["pre/tok"] = np.asarray(params["pre"]["tok"])
        weights["pre/pos"] = np.asarray(params["pre"]["pos"])
    for k, v in params["head"].items():
        weights[f"head/{k}"] = np.asarray(v)
    wf = f"{name}.weights.fxt"
    fxt.write(os.path.join(outdir, wf), weights)
    entry["weights_file"] = wf
    inf = f"{name}.init.fxt"
    fxt.write(os.path.join(outdir, inf), inits)
    entry["init_file"] = inf

    print(f"  [{name}] done in {time.time()-t0:.1f}s")
    return entry


# ---------------------------------------------------------------------------
# Dataset assembly (fixed multiples of CAL_B)
# ---------------------------------------------------------------------------

def make_datasets(name: str, cfg: dict, info: dict):
    out = {}
    if cfg["kind"] == "cnn":
        seed = info.get("eval_seed", 1000)
        xs, ys = D.gen_images(seed=seed, n=6000)
        (xtr, _), (xev, yev) = D.train_eval_split(xs, ys, 1024)
        out["calib_x"] = xtr[: cfg["calib_n"]].astype(np.float32)
        out["eval_x"] = xev.astype(np.float32)
        out["eval_y"] = yev
        return out
    if cfg["kind"] == "encoder":
        calib = []
        for task in D.NLU_TASKS:
            toks, ys, _ = D.gen_nlu(task, D.NLU_SEEDS[task], 5000)
            (xtr, _), (xev, yev) = D.train_eval_split(toks, ys, 1024)
            calib.append(xtr[: cfg["calib_n"] // 4])
            out[f"eval_{task}_x"] = xev[:512]
            out[f"eval_{task}_y"] = yev[:512]
        sp_toks, sp_s, sp_e = D.gen_span(D.NLU_SEEDS["entail"] + 500, 5000)
        (xtr, _), (xev, lab) = D.train_eval_split(
            sp_toks, np.stack([sp_s, sp_e], 1), 1024)
        calib.append(xtr[: cfg["calib_n"] // 4])
        out["eval_span_x"] = xev[:512]
        out["eval_span_y"] = lab[:512]
        out["calib_x"] = np.concatenate(calib)[: cfg["calib_n"]]
        return out
    # decoders
    if name == "dec_lora":
        seen = [c for c in range(D.D2T_NKEYS) if c not in D.D2T_UNSEEN]
        toks, _ = D.gen_d2t(5050, 3000, categories=seen)
        out["calib_x"] = toks[: cfg["calib_n"]]
        ev_seen, st_seen = D.gen_d2t(7070, 192, categories=seen)
        ev_uns, st_uns = D.gen_d2t(7171, 192, categories=list(D.D2T_UNSEEN))
        out["eval_seen_x"], out["eval_seen_start"] = ev_seen, st_seen
        out["eval_unseen_x"], out["eval_unseen_start"] = ev_uns, st_uns
        return out
    corpus = info.get("corpus", "lm-a")
    toks, _ = D.gen_corpus(corpus, 4096)
    out["calib_x"] = toks[: cfg["calib_n"]]
    out["eval_x"] = toks[-512:]
    if name == "llm_mini":
        for task in D.MC_TASKS:
            ch, ans = D.gen_mc(task, D.MC_SEEDS[task], 256)
            out[f"mc_{task}_x"] = ch.reshape(-1, ch.shape[-1])
            out[f"mc_{task}_y"] = ans
    return out


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=ART)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--models", nargs="*", default=None)
    args = ap.parse_args()
    outdir = os.path.abspath(args.out)
    os.makedirs(outdir, exist_ok=True)
    em = Emitter(outdir, args.force)

    names = args.models or list(MODEL_CFG)
    manifest = {"version": 1, "calib_batch": CAL_B,
                "scalars_recon": ["qmin_w", "qmax_w", "qmin_a", "qmax_a",
                                  "drop_p", "beta", "lr", "t", "seed"],
                "scalars_q": ["qmin_w", "qmax_w", "qmin_a", "qmax_a"],
                "models": {}}
    mpath = os.path.join(outdir, "manifest.json")
    if os.path.exists(mpath) and not args.force:
        with open(mpath) as f:
            manifest = json.load(f)

    t0 = time.time()
    for name in names:
        manifest["models"][name] = build_model(name, MODEL_CFG[name], em, outdir)
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"AOT complete: {em.count} lowered, {em.skipped} cached, "
          f"{time.time()-t0:.0f}s → {outdir}")


if __name__ == "__main__":
    main()
