"""Build-time pre-training / fine-tuning of the model zoo.

This is the substitute for "download a pre-trained checkpoint": every model
the paper quantizes is trained here, once, on the synthetic analog datasets,
and cached under `artifacts/ckpt/`.  Python-only, never on the request path.

Training budgets are sized for a single CPU core (each model trains in well
under a minute at these scales); the point is a *converged, non-trivial*
model whose accuracy/perplexity degrades measurably under quantization —
absolute SOTA is irrelevant to reproducing the paper's method ordering.
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from compile import data as D
from compile import models as M

CKPT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "ckpt")

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8
BN_MOMENTUM = 0.9


def _adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return z, jax.tree_util.tree_map(jnp.zeros_like, params)


def _adam_step(params, grads, m, v, t, lr):
    m = jax.tree_util.tree_map(lambda a, g: ADAM_B1 * a + (1 - ADAM_B1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda a, g: ADAM_B2 * a + (1 - ADAM_B2) * g * g, v, grads)
    b1t = 1 - ADAM_B1 ** t
    b2t = 1 - ADAM_B2 ** t
    params = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / b1t) / (jnp.sqrt(vv / b2t) + ADAM_EPS),
        params, m, v)
    return params, m, v


def _xent(logits, y):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, y[..., None], axis=-1).mean()


def _lm_loss(logits, toks):
    """Next-token cross entropy over positions 0..T−2 → targets 1..T−1,
    ignoring PAD targets."""
    tgt = toks[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    mask = (tgt != D.PAD).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Generic trainer
# ---------------------------------------------------------------------------

def train_model(model: M.QModel, xs, ys, steps: int, lr: float, batch: int,
                seed: int, loss_kind: str, init_gain: float = 1.0,
                log_every: int = 0) -> Dict:
    params = M.init_model(model, seed, init_gain)
    m, v = _adam_init(params)
    rng = np.random.default_rng(seed + 5)
    is_cnn = model.kind == "cnn"

    def loss_fn(p, xb, yb):
        out, stats = M.forward_train(model, p, xb, train=True)
        if loss_kind == "cls":
            loss = _xent(out, yb)
        elif loss_kind == "lm":
            loss = _lm_loss(out, xb)
        elif loss_kind == "span":
            s_log, e_log = out
            loss = _xent(s_log, yb[:, 0]) + _xent(e_log, yb[:, 1])
        else:
            raise ValueError(loss_kind)
        return loss, stats

    @jax.jit
    def step_fn(p, m, v, t, xb, yb):
        (loss, stats), grads = jax.value_and_grad(loss_fn, has_aux=True)(p, xb, yb)
        p, m, v = _adam_step(p, grads, m, v, t, lr)
        return p, m, v, loss, stats

    n = len(xs)
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        xb = jnp.asarray(xs[idx])
        yb = jnp.asarray(ys[idx]) if ys is not None else jnp.zeros(batch, jnp.int32)
        params, m, v, loss, stats = step_fn(params, m, v, float(t), xb, yb)
        if is_cnn and stats:
            params = _bn_ema(model, params, stats)
        if log_every and t % log_every == 0:
            print(f"    [{model.name}] step {t}/{steps} loss {float(loss):.4f}")
    return params


def _bn_ema(model, params, stats):
    for (uname, lname), (mu, var) in stats.items():
        bn = params["units"][uname]["bn"][lname]
        bn["mean"] = BN_MOMENTUM * bn["mean"] + (1 - BN_MOMENTUM) * mu
        bn["var"] = BN_MOMENTUM * bn["var"] + (1 - BN_MOMENTUM) * var
    return params


# ---------------------------------------------------------------------------
# Eval helpers (used for reporting full-precision baselines at build time)
# ---------------------------------------------------------------------------

def eval_cls(model, params, xs, ys, batch=64):
    correct = 0
    for i in range(0, len(xs), batch):
        logits, _ = M.forward_train(model, params, jnp.asarray(xs[i : i + batch]),
                                    train=False)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])).sum())
    return correct / len(xs)


def eval_ppl(model, params, toks, batch=64):
    tot, cnt = 0.0, 0.0
    for i in range(0, len(toks), batch):
        xb = jnp.asarray(toks[i : i + batch])
        logits, _ = M.forward_train(model, params, xb, train=False)
        tgt = xb[:, 1:]
        lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
        mask = (tgt != D.PAD).astype(jnp.float32)
        tot += float((nll * mask).sum())
        cnt += float(mask.sum())
    return float(np.exp(tot / max(cnt, 1.0)))


# ---------------------------------------------------------------------------
# Multi-task NLU encoder (GLUE-analog + span head), round-robin over tasks
# ---------------------------------------------------------------------------

def train_encoder_multi(model: M.QModel, steps: int, lr: float, batch: int,
                        seed: int):
    params = M.init_model(model, seed)
    m, v = _adam_init(params)
    rng = np.random.default_rng(seed + 5)

    datasets = {}
    for task in D.NLU_TASKS:
        toks, ys, _ = D.gen_nlu(task, D.NLU_SEEDS[task], 5000)
        datasets[task] = D.train_eval_split(toks, ys, 1024)
    sp_toks, sp_s, sp_e = D.gen_span(D.NLU_SEEDS["entail"] + 500, 5000)
    sp_lab = np.stack([sp_s, sp_e], axis=1)
    datasets["span"] = D.train_eval_split(sp_toks, sp_lab, 1024)

    def loss_fn(p, xb, yb, task):
        out, _ = M.forward_train(model, p, xb, train=True, task=task)
        if task == "span":
            s_log, e_log = out
            return _xent(s_log, yb[:, 0]) + _xent(e_log, yb[:, 1])
        return _xent(out, yb)

    step_fns = {
        task: jax.jit(
            lambda p, m, v, t, xb, yb, _task=task: _multi_step(
                loss_fn, p, m, v, t, xb, yb, _task, lr))
        for task in list(D.NLU_TASKS) + ["span"]
    }

    tasks = list(D.NLU_TASKS) + ["span"]
    for t in range(1, steps + 1):
        task = tasks[t % len(tasks)]
        (xtr, ytr), _ = datasets[task]
        idx = rng.integers(0, len(xtr), size=batch)
        params, m, v, _ = step_fns[task](params, m, v, float(t),
                                         jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))

    accs = {}
    for task in D.NLU_TASKS:
        _, (xev, yev) = datasets[task]
        accs[task] = round(eval_cls_task(model, params, xev, yev, task), 4)
    _, (xev, yev) = datasets["span"]
    accs["span_em"] = round(eval_span(model, params, xev, yev), 4)
    return params, accs


def _multi_step(loss_fn, p, m, v, t, xb, yb, task, lr):
    loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb, task)
    p, m, v = _adam_step(p, grads, m, v, t, lr)
    return p, m, v, loss


def eval_cls_task(model, params, xs, ys, task, batch=64):
    correct = 0
    for i in range(0, len(xs), batch):
        logits, _ = M.forward_train(model, params, jnp.asarray(xs[i : i + batch]),
                                    train=False, task=task)
        correct += int((jnp.argmax(logits, -1) == jnp.asarray(ys[i : i + batch])).sum())
    return correct / len(xs)


def eval_span(model, params, xs, labs, batch=64):
    """Exact-match over (start, end) — the F1/EM analog for Table 12."""
    em = 0
    for i in range(0, len(xs), batch):
        (s_log, e_log), _ = M.forward_train(
            model, params, jnp.asarray(xs[i : i + batch]), train=False, task="span")
        ps = jnp.argmax(s_log, -1)
        pe = jnp.argmax(e_log, -1)
        yb = labs[i : i + batch]
        em += int(((ps == jnp.asarray(yb[:, 0])) & (pe == jnp.asarray(yb[:, 1]))).sum())
    return em / len(xs)


# ---------------------------------------------------------------------------
# LoRA fine-tuning (dec_lora on synth-d2t, Table 6 pipeline)
# ---------------------------------------------------------------------------

def train_lora(model: M.QModel, params, toks, steps: int, lr: float,
               batch: int, seed: int):
    adapters = M.lora_init(model, seed)
    m, v = _adam_init(adapters)
    rng = np.random.default_rng(seed + 9)

    def loss_fn(ad, xb):
        logits = M.forward_lora(model, params, ad, xb)
        return _lm_loss(logits, xb)

    @jax.jit
    def step_fn(ad, m, v, t, xb):
        loss, grads = jax.value_and_grad(loss_fn)(ad, xb)
        ad, m, v = _adam_step(ad, grads, m, v, t, lr)
        return ad, m, v, loss

    for t in range(1, steps + 1):
        idx = rng.integers(0, len(toks), size=batch)
        adapters, m, v, loss = step_fn(adapters, m, v, float(t), jnp.asarray(toks[idx]))
    return adapters


# ---------------------------------------------------------------------------
# Zoo recipes — dataset + budget per model, with checkpoint caching
# ---------------------------------------------------------------------------

def _ckpt_path(name: str, seed: int) -> str:
    return os.path.join(CKPT_DIR, f"{name}_seed{seed}.pkl")


def load_or_train(name: str, seed: int = 0, force: bool = False):
    """Returns (model, folded_params, info).  `info` carries the eval data
    and fp metrics for this checkpoint (consumed by aot.py's manifest)."""
    os.makedirs(CKPT_DIR, exist_ok=True)
    path = _ckpt_path(name, seed)
    if not force and os.path.exists(path):
        with open(path, "rb") as f:
            blob = pickle.load(f)
        model = M.MODEL_BUILDERS[name]()
        return model, jax.tree_util.tree_map(jnp.asarray, blob["params"]), blob["info"]

    t0 = time.time()
    model, params, info = _train_recipe(name, seed)
    info["train_seconds"] = round(time.time() - t0, 1)
    blob = {"params": jax.tree_util.tree_map(np.asarray, params), "info": info}
    with open(path, "wb") as f:
        pickle.dump(blob, f)
    print(f"  trained {name} (seed {seed}) in {info['train_seconds']}s: {info.get('fp_metric')}")
    return model, params, info


def _train_recipe(name: str, seed: int):
    base = name.replace("_alt", "")
    if name.endswith("_alt"):
        seed = seed + 17   # "different checkpoint" — Tables 8/9

    if base in ("tinyresnet_a", "tinyresnet_b", "tinymobilenet"):
        model = M.MODEL_BUILDERS[name]()
        xs, ys = D.gen_images(seed=1000 + seed, n=6000)
        (xtr, ytr), (xev, yev) = D.train_eval_split(xs, ys, 1024)
        gain = 2.5 if base == "tinymobilenet" else 1.0
        params = train_model(model, xtr, ytr, steps=900, lr=2e-3, batch=64,
                             seed=seed, loss_kind="cls", init_gain=gain)
        acc = eval_cls(model, params, xev, yev)
        params = M.fold_bn(model, params)
        info = {"task": "image", "fp_metric": {"top1": round(acc, 4)},
                "eval_seed": 1000 + seed}
        return model, params, info

    if name.startswith(("dec_small", "dec_med")) or name == "llm_mini":
        model = M.MODEL_BUILDERS[name]()
        corpus = "lm-b" if name.endswith("lmb") else "lm-a"
        toks, ent = D.gen_corpus(corpus, 4096)
        steps = 2600 if name == "llm_mini" else (2000 if "small" in name else 2200)
        params = train_model(model, toks[:-512], None, steps=steps, lr=3e-3,
                             batch=48, seed=seed, loss_kind="lm")
        ppl = eval_ppl(model, params, toks[-512:])
        info = {"task": "lm", "corpus": corpus, "fp_metric": {"ppl": round(ppl, 3)},
                "grammar_entropy": round(ent, 3)}
        return model, params, info

    if name in ("enc_small", "enc_base"):
        model = M.MODEL_BUILDERS[name]()
        steps = 1800 if name == "enc_small" else 2000
        params, accs = train_encoder_multi(model, steps=steps, lr=1e-3,
                                           batch=32, seed=seed)
        info = {"task": "nlu", "fp_metric": accs}
        return model, params, info

    if name == "dec_lora":
        model = M.MODEL_BUILDERS[name]()
        # base pre-training on generic d2t-vocab sequences
        base, _ = D.gen_lm(4040, 3000, branch=6, temperature=1.0,
                           vocab=D.D2T_VOCAB, seq=D.D2T_SEQ)
        params = train_model(model, base, None, steps=500, lr=2e-3, batch=32,
                             seed=seed, loss_kind="lm")
        # LoRA fine-tune on *seen* categories only (unseen held out, Table 6)
        seen = [c for c in range(D.D2T_NKEYS) if c not in D.D2T_UNSEEN]
        toks, _ = D.gen_d2t(5050, 3000, categories=seen)
        adapters = train_lora(model, params, toks, steps=600, lr=5e-3,
                              batch=32, seed=seed)
        params = M.lora_merge(model, params, adapters)
        ppl = eval_ppl(model, params, toks[-256:])
        info = {"task": "d2t", "fp_metric": {"ft_ppl": round(ppl, 3)},
                "seen_categories": seen}
        return model, params, info

    raise ValueError(name)


if __name__ == "__main__":
    import sys
    names = sys.argv[1:] or list(M.MODEL_BUILDERS)
    for n in names:
        load_or_train(n)
