"""Pure-jnp oracles for every quantization kernel in this package.

These are the *correctness ground truth* for the Pallas kernels: the pytest
suite asserts `kernels.* ≈ ref.*` across shape / bit-width / scale sweeps,
and the custom-VJP backward rules are checked against both finite
differences of these oracles and the closed forms of Proposition 3.1 in the
FlexRound paper (Lee et al., ICML 2023).

Notation follows the paper (§3):

    Ŵ = s1 · clip( round( W / (s1 ⊙ S2 ⊙ s3 [⊙ s4]) ), qmin, qmax )

with `s1` a common (per-tensor scalar or per-channel row vector) grid size,
`S2` an elementwise scale of W's shape, `s3` a per-output-channel scale and
`s4` a per-input-channel scale (2D convolutions only).  All kernels here
operate on the canonical 2D layout `(rows, cols) = (C_out, C_in·Kh·Kw)`;
reshaping to/from conv layouts happens in `compile.quant`.
"""
from __future__ import annotations

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Rounding-to-nearest (RTN) — the no-learning baseline every method starts at.
# ---------------------------------------------------------------------------

def rtn(w, s1, qmin, qmax, zero_point=0.0):
    """Symmetric/asymmetric rounding-to-nearest.

    w        : (r, c) weights
    s1       : scalar or (r, 1) grid size
    zero_point: scalar or (r, 1) integer zero point (0 → symmetric)
    """
    n = jnp.round(w / s1) + zero_point
    n = jnp.clip(n, qmin, qmax)
    return s1 * (n - zero_point)


def rtn_int(w, s1, qmin, qmax, zero_point=0.0):
    """Integer grid indices produced by RTN (used by grid-shift analysis)."""
    return jnp.clip(jnp.round(w / s1) + zero_point, qmin, qmax)


# ---------------------------------------------------------------------------
# FlexRound (Eq. 2 of the paper)
# ---------------------------------------------------------------------------

def flexround_divisor(s1, s2, s3=None, s4=None):
    """S = s1 ⊙ S2 ⊙ s3 ⊙ s4 with broadcasting; `None` drops a factor."""
    s = s1 * s2
    if s3 is not None:
        s = s * s3
    if s4 is not None:
        s = s * s4
    return s


def flexround(w, s1, s2, s3=None, s4=None, qmin=-8, qmax=7, zero_point=0.0):
    """Forward fake-quant of FlexRound.

    w  : (r, c)
    s1 : scalar or (r, 1)      — learnable grid size
    s2 : (r, c)                — learnable elementwise divisor
    s3 : (r, 1) or None        — learnable per-output-channel scale
    s4 : (1, c) or None        — learnable per-input-channel scale (convs;
                                 already expanded to the flattened column
                                 layout by the caller)
    zero_point: 0 for the symmetric scheme; fixed asymmetric offset otherwise.
    """
    div = flexround_divisor(s1, s2, s3, s4)
    n = jnp.round(w / div) + zero_point
    n = jnp.clip(n, qmin, qmax)
    return s1 * (n - zero_point)


def flexround_int(w, s1, s2, s3=None, s4=None, qmin=-8, qmax=7, zero_point=0.0):
    div = flexround_divisor(s1, s2, s3, s4)
    return jnp.clip(jnp.round(w / div) + zero_point, qmin, qmax)


def flexround_bwd(w, s1, s2, s3, s4, qmin, qmax, zero_point, g):
    """Closed-form STE cotangents (Proposition 3.1 + the s1 chain rule).

    Returns (ds1, dS2, ds3, ds4) matching the parameter shapes.  The
    straight-through estimator treats round(·) as identity inside the clip
    range; outside the range the rounding path contributes nothing but the
    `s1 · (n_c − z)` product-rule term survives.
    """
    div = flexround_divisor(s1, s2, s3, s4)
    r = w / div
    n = jnp.round(r) + zero_point
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    n_c = jnp.clip(n, qmin, qmax)

    # dŴ/ds1 = (n_c − z) + s1 · mask · ∂r/∂s1,  ∂r/∂s1 = −r/s1
    ds1_full = g * ((n_c - zero_point) - inside * r)
    if jnp.ndim(s1) == 0:
        ds1 = jnp.sum(ds1_full)
    else:
        ds1 = jnp.sum(ds1_full, axis=1, keepdims=True)

    # dŴ/dS2 = s1 · mask · (−r / S2)  — Proposition 3.1: ∝ −W/S'² · ∂L/∂Ŵ
    common = g * s1 * inside * (-r)
    ds2 = common / s2

    ds3 = None
    if s3 is not None:
        ds3 = jnp.sum(common / s3, axis=1, keepdims=True)
    ds4 = None
    if s4 is not None:
        ds4 = jnp.sum(common / s4, axis=0, keepdims=True)
    return ds1, ds2, ds3, ds4


# ---------------------------------------------------------------------------
# AdaRound (Nagel et al., 2020) — element-wise addition baseline.
# ---------------------------------------------------------------------------

ADAROUND_GAMMA = -0.1
ADAROUND_ZETA = 1.2


def sigmoid(v):
    return 1.0 / (1.0 + jnp.exp(-v))


def adaround_h(v):
    """Rectified sigmoid h(V) = clip(σ(V)·(ζ−γ) + γ, 0, 1)."""
    return jnp.clip(sigmoid(v) * (ADAROUND_ZETA - ADAROUND_GAMMA) + ADAROUND_GAMMA, 0.0, 1.0)


def adaround_init_v(w, s1):
    """Initialize V so that h(V) equals the fractional part of W/s1 — i.e.
    AdaRound's soft quantizer starts at the rounding-to-nearest solution."""
    frac = w / s1 - jnp.floor(w / s1)
    frac = jnp.clip(frac, 1e-4, 1.0 - 1e-4)
    p = (frac - ADAROUND_GAMMA) / (ADAROUND_ZETA - ADAROUND_GAMMA)
    return -jnp.log(1.0 / p - 1.0)


def adaround(w, s1, v, qmin, qmax, zero_point=0.0, hard=False):
    """Ŵ = s1 · (clip(floor(W/s1) + h(V) + z, qmin, qmax) − z).

    `hard=True` snaps h(V) to {0,1} — the deployment-time rounding."""
    h = adaround_h(v)
    if hard:
        h = (h >= 0.5).astype(w.dtype)
    n = jnp.floor(w / s1) + h + zero_point
    n = jnp.clip(n, qmin, qmax)
    return s1 * (n - zero_point)


def adaround_reg(v, beta):
    """f_reg(V) = Σ 1 − |2h(V) − 1|^β  (annealed β; pulls h to {0,1})."""
    h = adaround_h(v)
    return jnp.sum(1.0 - jnp.abs(2.0 * h - 1.0) ** beta)


def adaround_bwd(w, s1, v, qmin, qmax, zero_point, g):
    """STE cotangent for V (s1 is fixed in AdaRound)."""
    h_raw = sigmoid(v) * (ADAROUND_ZETA - ADAROUND_GAMMA) + ADAROUND_GAMMA
    mask_h = ((h_raw > 0.0) & (h_raw < 1.0)).astype(w.dtype)
    dh = sigmoid(v) * (1.0 - sigmoid(v)) * (ADAROUND_ZETA - ADAROUND_GAMMA) * mask_h
    n = jnp.floor(w / s1) + adaround_h(v) + zero_point
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    return g * s1 * inside * dh


# ---------------------------------------------------------------------------
# AdaQuant (Hubara et al., 2021) — learn s1 and an additive perturbation V.
# ---------------------------------------------------------------------------

def adaquant(w, s1, v, qmin, qmax, zero_point=0.0):
    n = jnp.round((w + v) / s1) + zero_point
    n = jnp.clip(n, qmin, qmax)
    return s1 * (n - zero_point)


def adaquant_bwd(w, s1, v, qmin, qmax, zero_point, g):
    r = (w + v) / s1
    n = jnp.round(r) + zero_point
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    dv = g * inside
    ds1_full = g * ((n_c - zero_point) - inside * r)
    ds1 = jnp.sum(ds1_full) if jnp.ndim(s1) == 0 else jnp.sum(ds1_full, axis=1, keepdims=True)
    return ds1, dv


# ---------------------------------------------------------------------------
# AdaQuant + FlexRound combination (Appendix F)
# ---------------------------------------------------------------------------

def adaquant_flexround(w, s1, v, s2, s3=None, s4=None, qmin=-8, qmax=7, zero_point=0.0):
    """Ŵ = s1·(clip(round((W+V)/(s1⊙S2⊙s3⊙s4)) + z, qmin, qmax) − z) — the
    naive union of an additive perturbation with the divisive scales."""
    div = flexround_divisor(s1, s2, s3, s4)
    n = jnp.round((w + v) / div) + zero_point
    n = jnp.clip(n, qmin, qmax)
    return s1 * (n - zero_point)


# ---------------------------------------------------------------------------
# LSQ activation fake-quant (Esser et al., 2020), the "A" in W/A bits.
# ---------------------------------------------------------------------------

def lsq_act(x, step, qmin, qmax, zero_point=0.0):
    n = jnp.round(x / step) + zero_point
    n = jnp.clip(n, qmin, qmax)
    return step * (n - zero_point)


def lsq_grad_scale(x, qmax):
    """LSQ gradient scale 1/√(N·qmax)."""
    return 1.0 / jnp.sqrt(x.size * jnp.maximum(jnp.asarray(qmax, jnp.float32), 1.0))


def lsq_act_bwd(x, step, qmin, qmax, zero_point, g):
    r = x / step
    n = jnp.round(r) + zero_point
    inside = ((n >= qmin) & (n <= qmax)).astype(x.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    dx = g * inside
    gscale = lsq_grad_scale(x, qmax)
    dstep = jnp.sum(g * ((n_c - zero_point) - inside * r)) * gscale
    return dx, dstep


# ---------------------------------------------------------------------------
# Fused fake-quant + matmul — the reconstruction hot path  Ŷ = X̃ · Ŵᵀ
# ---------------------------------------------------------------------------

def flexround_matmul(w, s1, s2, s3, s4, qmin, qmax, zero_point, x):
    """Reference for the fused kernel: fake-quant W then contract with X̃.

    x : (batch, c) activations; returns (batch, r)."""
    w_hat = flexround(w, s1, s2, s3, s4, qmin, qmax, zero_point)
    return x @ w_hat.T


# ---------------------------------------------------------------------------
# Quantization grid helpers
# ---------------------------------------------------------------------------

def qrange(bits: int, symmetric: bool):
    """Integer grid limits for a bit-width.  Symmetric grids are the signed
    two's-complement range; asymmetric grids are unsigned [0, 2^b − 1]."""
    if symmetric:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    return 0, 2**bits - 1


def minmax_scale(w, bits: int, symmetric: bool, per_channel: bool = False):
    """Min/max calibration of (s1, zero_point) — the init every learnable
    method starts from.  Returns (s1, zero_point) with shapes () / (r,1)."""
    qmin, qmax = qrange(bits, symmetric)
    axis = 1 if per_channel else None
    if symmetric:
        amax = jnp.max(jnp.abs(w), axis=axis, keepdims=per_channel)
        s1 = jnp.maximum(amax / qmax, 1e-8)
        zp = jnp.zeros_like(s1)
    else:
        wmax = jnp.max(w, axis=axis, keepdims=per_channel)
        wmin = jnp.min(w, axis=axis, keepdims=per_channel)
        s1 = jnp.maximum((wmax - wmin) / (qmax - qmin), 1e-8)
        # zp maps wmin → qmin; deliberately NOT clamped to the grid so
        # one-sided data keeps its full range under fake quantization.
        zp = qmin - jnp.round(wmin / s1)
    if not per_channel:
        s1 = jnp.reshape(s1, ())
        zp = jnp.reshape(zp, ())
    return s1, zp
