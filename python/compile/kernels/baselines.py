"""Layer-1 Pallas kernels for the baseline rounding schemes.

The paper compares FlexRound against the element-wise-*addition* family:

* RTN       — rounding-to-nearest, the zero-parameter baseline.
* AdaRound  — Ŵ = s1·(clip(⌊W/s1⌋ + h(V) + z) − z), learnable V, fixed s1.
* AdaQuant  — Ŵ = s1·(clip(round((W+V)/s1) + z) − z), learnable V and s1.
* LSQ       — activation fake-quant with a learned step size.

Same canonical 2D layout and tiling discipline as `flexround.py`; per-row
scales are (r, 1), zero-points (r, 1), and everything runs `interpret=True`
so the lowered HLO executes on the CPU PJRT client loaded from Rust.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.flexround import (
    BLOCK_R,
    _blocks,
    _col_spec,
    _q_spec,
    _row_spec,
    _scalar11,
    _tile_spec,
)

ADAROUND_GAMMA = -0.1
ADAROUND_ZETA = 1.2


# ---------------------------------------------------------------------------
# RTN
# ---------------------------------------------------------------------------

def _rtn_kernel(w_ref, s1_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    n = jnp.clip(jnp.round(w / s1) + zp, qmin, qmax)
    o_ref[...] = s1 * (n - zp)


def rtn(w, s1, zp, qmin, qmax):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _rtn_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[_tile_spec(br, bc), _row_spec(br), _row_spec(br),
                  _q_spec(), _q_spec()],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, zp, _scalar11(qmin), _scalar11(qmax))


# ---------------------------------------------------------------------------
# AdaRound
# ---------------------------------------------------------------------------

def _adaround_kernel(w_ref, s1_ref, v_ref, zp_ref, qmin_ref, qmax_ref, o_ref, *, hard):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    sig = 1.0 / (1.0 + jnp.exp(-v_ref[...]))
    h = jnp.clip(sig * (ADAROUND_ZETA - ADAROUND_GAMMA) + ADAROUND_GAMMA, 0.0, 1.0)
    if hard:
        h = (h >= 0.5).astype(w.dtype)
    n = jnp.clip(jnp.floor(w / s1) + h + zp, qmin, qmax)
    o_ref[...] = s1 * (n - zp)


def adaround(w, s1, v, zp, qmin, qmax, hard=False):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        functools.partial(_adaround_kernel, hard=hard),
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[_tile_spec(br, bc), _row_spec(br), _tile_spec(br, bc),
                  _row_spec(br), _q_spec(), _q_spec()],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, v, zp, _scalar11(qmin), _scalar11(qmax))


def _adaround_bwd_kernel(w_ref, s1_ref, v_ref, zp_ref, g_ref, qmin_ref, qmax_ref, dv_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    sig = 1.0 / (1.0 + jnp.exp(-v_ref[...]))
    h_raw = sig * (ADAROUND_ZETA - ADAROUND_GAMMA) + ADAROUND_GAMMA
    mask_h = ((h_raw > 0.0) & (h_raw < 1.0)).astype(w.dtype)
    dh = sig * (1.0 - sig) * (ADAROUND_ZETA - ADAROUND_GAMMA) * mask_h
    h = jnp.clip(h_raw, 0.0, 1.0)
    n = jnp.floor(w / s1) + h + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    dv_ref[...] = g_ref[...] * s1 * inside * dh


def adaround_bwd(w, s1, v, zp, g, qmin, qmax):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _adaround_bwd_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, v, zp, g, _scalar11(qmin), _scalar11(qmax))


# ---------------------------------------------------------------------------
# AdaQuant
# ---------------------------------------------------------------------------

def _adaquant_kernel(w_ref, s1_ref, v_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    n = jnp.clip(jnp.round((w + v_ref[...]) / s1) + zp, qmin, qmax)
    o_ref[...] = s1 * (n - zp)


def adaquant(w, s1, v, zp, qmin, qmax):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _adaquant_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[_tile_spec(br, bc), _row_spec(br), _tile_spec(br, bc),
                  _row_spec(br), _q_spec(), _q_spec()],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, v, zp, _scalar11(qmin), _scalar11(qmax))


def _adaquant_bwd_kernel(
    w_ref, s1_ref, v_ref, zp_ref, g_ref, qmin_ref, qmax_ref, dv_ref, ds1f_ref
):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    g = g_ref[...]
    r_ = (w + v_ref[...]) / s1
    n = jnp.round(r_) + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    dv_ref[...] = g * inside
    ds1f_ref[...] = g * ((n_c - zp) - inside * r_)


def adaquant_bwd(w, s1, v, zp, g, qmin, qmax):
    """Returns (dV, ds1_full); callers reduce ds1_full to s1's shape."""
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _adaquant_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((r, c), w.dtype),
            jax.ShapeDtypeStruct((r, c), w.dtype),
        ),
        grid=(gr, gc),
        in_specs=[
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=(_tile_spec(br, bc), _tile_spec(br, bc)),
        interpret=True,
    )(w, s1, v, zp, g, _scalar11(qmin), _scalar11(qmax))


# ---------------------------------------------------------------------------
# AdaQuant ⊕ FlexRound (Appendix F)
# ---------------------------------------------------------------------------

def _aqfr_kernel(w_ref, s1_ref, v_ref, s2_ref, s3_ref, s4_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    zp = zp_ref[...]
    div = s1 * s2_ref[...] * s3_ref[...] * s4_ref[...]
    n = jnp.clip(jnp.round((w + v_ref[...]) / div) + zp, qmin, qmax)
    o_ref[...] = s1 * (n - zp)


def adaquant_flexround(w, s1, v, s2, s3, s4, zp, qmin, qmax):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _aqfr_kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _tile_spec(br, bc),
            _row_spec(br),
            _col_spec(bc),
            _row_spec(br),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, v, s2, s3, s4, zp, _scalar11(qmin), _scalar11(qmax))


# ---------------------------------------------------------------------------
# LSQ activation fake-quant — operates on flattened (n, d) activations.
# ---------------------------------------------------------------------------

def _lsq_kernel(x_ref, step_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    x = x_ref[...]
    step = step_ref[...]
    zp = zp_ref[...]
    n = jnp.clip(jnp.round(x / step) + zp, qmin, qmax)
    o_ref[...] = step * (n - zp)


def lsq_act(x2d, step, zp, qmin, qmax):
    """x2d: (n, d); step/zp: (1, 1) scalars (per-tensor activation quant)."""
    n_, d = x2d.shape
    bn = min(BLOCK_R, n_)
    bd = min(BLOCK_R, d)
    grid = (pl.cdiv(n_, bn), pl.cdiv(d, bd))
    return pl.pallas_call(
        _lsq_kernel,
        out_shape=jax.ShapeDtypeStruct((n_, d), x2d.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        interpret=True,
    )(x2d, step, zp, _scalar11(qmin), _scalar11(qmax))


def _lsq_bwd_kernel(x_ref, step_ref, zp_ref, g_ref, qmin_ref, qmax_ref, dx_ref, dsf_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    x = x_ref[...]
    step = step_ref[...]
    zp = zp_ref[...]
    g = g_ref[...]
    r_ = x / step
    n = jnp.round(r_) + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(x.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    dx_ref[...] = g * inside
    dsf_ref[...] = g * ((n_c - zp) - inside * r_)


def lsq_act_bwd(x2d, step, zp, g, qmin, qmax):
    """Returns (dx, dstep_full); caller sums dstep_full × LSQ grad scale."""
    n_, d = x2d.shape
    bn = min(BLOCK_R, n_)
    bd = min(BLOCK_R, d)
    grid = (pl.cdiv(n_, bn), pl.cdiv(d, bd))
    return pl.pallas_call(
        _lsq_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n_, d), x2d.dtype),
            jax.ShapeDtypeStruct((n_, d), x2d.dtype),
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=(
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
            pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        ),
        interpret=True,
    )(x2d, step, zp, g, _scalar11(qmin), _scalar11(qmax))
