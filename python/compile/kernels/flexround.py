"""Layer-1 Pallas kernels for FlexRound fake quantization.

Three kernels make up the PTQ hot path:

* `flexround_fq`       — fused element-wise division → round → clamp → rescale
                         (Eq. 2 of the paper) producing the fake-quantized Ŵ.
* `flexround_fq_bwd`   — the STE backward pass: one fused pass produces the
                         element-wise factors of every cotangent (Proposition
                         3.1's reciprocal rule); the cheap row/col reductions
                         happen in the surrounding jnp graph.
* `flexround_matmul`   — fused fake-quant + contraction  Ŷ = X̃ · Ŵᵀ: the
                         reconstruction loss ‖WX − ŴX̃‖²_F evaluates this every
                         iteration, so Ŵ never round-trips to HBM per block.

TPU adaptation (see DESIGN.md §Hardware-Adaptation): blocks are shaped
`(BLOCK_R, BLOCK_C)` so a (W, S2) tile pair plus the per-row scales fit VMEM;
the per-row factors (s1, s3, zero-point) broadcast along the lane dimension
as sublane splats.  `interpret=True` everywhere — the CPU PJRT client cannot
execute Mosaic custom-calls, and the lowered HLO is what the Rust runtime
loads.

All kernels take the canonical 2D layout described in `ref.py`; the per-row
scales always arrive as `(r, 1)` arrays (callers broadcast scalars), and the
unused factors arrive as ones so a single kernel serves every FlexRound
variant (full, fixed-s1, no-s3s4 ablations).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes: the TPU VPU lane width is 128; eight sublanes of f32 per
# register row.  (128, 128) f32 tiles are 64 KiB each — W, S2, the integer
# tile and the output co-resident are ~256 KiB, far under the ~16 MiB VMEM
# budget, leaving room for double-buffered HBM prefetch of the next tile.
BLOCK_R = 128
BLOCK_C = 128


def _blocks(r: int, c: int):
    br = min(BLOCK_R, r)
    bc = min(BLOCK_C, c)
    return br, bc, pl.cdiv(r, br), pl.cdiv(c, bc)


# ---------------------------------------------------------------------------
# Forward fake-quant
# ---------------------------------------------------------------------------

def _fq_kernel(w_ref, s1_ref, s2_ref, s3_ref, s4_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]          # (br, 1) — sublane splat along lanes
    s2 = s2_ref[...]
    s3 = s3_ref[...]
    s4 = s4_ref[...]          # (1, bc)
    zp = zp_ref[...]
    div = s1 * s2 * s3 * s4
    n = jnp.round(w / div) + zp
    n = jnp.clip(n, qmin, qmax)
    o_ref[...] = s1 * (n - zp)


def _fq_int_kernel(w_ref, s1_ref, s2_ref, s3_ref, s4_ref, zp_ref, qmin_ref, qmax_ref, o_ref):
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    div = s1_ref[...] * s2_ref[...] * s3_ref[...] * s4_ref[...]
    n = jnp.round(w / div) + zp_ref[...]
    o_ref[...] = jnp.clip(n, qmin, qmax)


def _row_spec(br):
    return pl.BlockSpec((br, 1), lambda i, j: (i, 0))


def _col_spec(bc):
    return pl.BlockSpec((1, bc), lambda i, j: (0, j))


def _tile_spec(br, bc):
    return pl.BlockSpec((br, bc), lambda i, j: (i, j))


def _scalar11(x, dtype=None):
    """Normalize a python/0-d scalar to the (1,1) array the kernels expect."""
    import jax.numpy as _jnp
    a = _jnp.asarray(x, dtype or _jnp.float32)
    return a.reshape(1, 1)


def _q_spec():
    return pl.BlockSpec((1, 1), lambda i, j: (0, 0))


def _fq_call(kernel, w, s1, s2, s3, s4, zp, qmin, qmax):
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        grid=(gr, gc),
        in_specs=[
            _tile_spec(br, bc),   # W
            _row_spec(br),        # s1
            _tile_spec(br, bc),   # S2
            _row_spec(br),        # s3
            _col_spec(bc),        # s4
            _row_spec(br),        # zero point
            _q_spec(),            # qmin
            _q_spec(),            # qmax
        ],
        out_specs=_tile_spec(br, bc),
        interpret=True,
    )(w, s1, s2, s3, s4, zp, _scalar11(qmin), _scalar11(qmax))


def flexround_fq(w, s1, s2, s3, s4, zp, qmin, qmax):
    """Fused FlexRound fake-quant.  s1/s3/zp: (r,1); s4: (1,c); S2: (r,c)."""
    return _fq_call(_fq_kernel, w, s1, s2, s3, s4, zp, qmin, qmax)


def flexround_fq_int(w, s1, s2, s3, s4, zp, qmin, qmax):
    """Integer grid indices (for export / grid-shift analysis)."""
    return _fq_call(_fq_int_kernel, w, s1, s2, s3, s4, zp, qmin, qmax)


# ---------------------------------------------------------------------------
# Backward (STE) — element-wise factors in one fused pass
# ---------------------------------------------------------------------------

def _fq_bwd_kernel(
    w_ref, s1_ref, s2_ref, s3_ref, s4_ref, zp_ref, g_ref, qmin_ref, qmax_ref,
    ds1f_ref, common_ref
):
    """Produces the two element-wise fields every cotangent reduces from:

       ds1_full = g · ((n_c − z) − inside·r)         (LSQ-style grid-size grad)
       common   = g · s1 · inside · (−r)             (Prop. 3.1 numerator)

    with  dS2 = common/S2,  ds3 = rowsum(common)/s3,  ds4 = colsum(common)/s4.
    The divisions/reductions are O(r+c) work left to XLA fusion outside.
    """
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    div = s1 * s2_ref[...] * s3_ref[...] * s4_ref[...]
    zp = zp_ref[...]
    g = g_ref[...]
    r_ = w / div
    n = jnp.round(r_) + zp
    inside = ((n >= qmin) & (n <= qmax)).astype(w.dtype)
    n_c = jnp.clip(n, qmin, qmax)
    ds1f_ref[...] = g * ((n_c - zp) - inside * r_)
    common_ref[...] = g * s1 * inside * (-r_)


def flexround_fq_bwd(w, s1, s2, s3, s4, zp, g, qmin, qmax):
    """Fused element-wise backward; returns (ds1_full, common)."""
    r, c = w.shape
    br, bc, gr, gc = _blocks(r, c)
    return pl.pallas_call(
        _fq_bwd_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((r, c), w.dtype),
            jax.ShapeDtypeStruct((r, c), w.dtype),
        ),
        grid=(gr, gc),
        in_specs=[
            _tile_spec(br, bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _row_spec(br),
            _col_spec(bc),
            _row_spec(br),
            _tile_spec(br, bc),
            _q_spec(),
            _q_spec(),
        ],
        out_specs=(_tile_spec(br, bc), _tile_spec(br, bc)),
        interpret=True,
    )(w, s1, s2, s3, s4, zp, g, _scalar11(qmin), _scalar11(qmax))


# ---------------------------------------------------------------------------
# Fused fake-quant + matmul:  Ŷ = X̃ · Ŵᵀ
# ---------------------------------------------------------------------------

def _fq_matmul_kernel(
    x_ref, w_ref, s1_ref, s2_ref, s3_ref, s4_ref, zp_ref, qmin_ref, qmax_ref, o_ref
):
    """One (batch-tile × row-tile) output block.  The Ŵ tile is produced
    in-register and fed straight into the MXU-shaped contraction — it never
    leaves VMEM.  K is kept whole per block (our layer widths fit VMEM); a
    K-loop with an accumulator is the extension point for wider layers."""
    qmin = qmin_ref[0, 0]
    qmax = qmax_ref[0, 0]
    w = w_ref[...]
    s1 = s1_ref[...]
    div = s1 * s2_ref[...] * s3_ref[...] * s4_ref[...]
    zp = zp_ref[...]
    n = jnp.clip(jnp.round(w / div) + zp, qmin, qmax)
    w_hat = s1 * (n - zp)
    o_ref[...] = jnp.dot(x_ref[...], w_hat.T, preferred_element_type=jnp.float32)


def flexround_matmul(x, w, s1, s2, s3, s4, zp, qmin, qmax):
    """x: (b, c) activations, w: (r, c) weights → (b, r)."""
    b, c = x.shape
    r, c2 = w.shape
    assert c == c2, f"contraction mismatch {x.shape} vs {w.shape}"
    bb = min(BLOCK_R, b)
    br = min(BLOCK_R, r)
    grid = (pl.cdiv(b, bb), pl.cdiv(r, br))
    return pl.pallas_call(
        _fq_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((b, r), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, c), lambda i, j: (i, 0)),   # X̃ batch tile
            pl.BlockSpec((br, c), lambda i, j: (j, 0)),   # W row tile
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),   # s1
            pl.BlockSpec((br, c), lambda i, j: (j, 0)),   # S2
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),   # s3
            pl.BlockSpec((1, c), lambda i, j: (0, 0)),    # s4
            pl.BlockSpec((br, 1), lambda i, j: (j, 0)),   # zp
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),    # qmin
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),    # qmax
        ],
        out_specs=pl.BlockSpec((bb, br), lambda i, j: (i, j)),
        interpret=True,
    )(x, w, s1, s2, s3, s4, zp, _scalar11(qmin), _scalar11(qmax))


def vmem_bytes_estimate(r: int, c: int, batch: int = 0) -> int:
    """Static VMEM footprint estimate for one grid step of the fused matmul
    (or fake-quant when batch == 0).  Used by DESIGN/EXPERIMENTS §Perf and by
    `aot.py` to refuse block shapes that would not fit a real TPU core."""
    br = min(BLOCK_R, r)
    bc = min(BLOCK_C, c)
    tiles = 3  # W, S2, Ŵ/int tile
    n = tiles * br * bc + 3 * br + bc
    if batch:
        bb = min(BLOCK_R, batch)
        n += bb * bc + bb * br  # X̃ tile + output tile
    return 4 * n
