"""FXT — the tiny named-tensor container shared by Python (writer) and Rust
(reader + writer).  Little-endian throughout.

Layout:
    magic   : 4 bytes  b"FXT1"
    count   : u32      number of tensors
    per tensor:
        name_len : u32
        name     : utf-8 bytes
        dtype    : u8   (0 = f32, 1 = i32)
        ndim     : u8
        dims     : u32 × ndim
        data     : raw little-endian values (prod(dims) elements)

The Rust side lives in `rust/src/ser/fxt.rs`; `python/tests/test_fxt.py` and
`rust/tests/` both round-trip the same reference buffers.
"""
from __future__ import annotations

import struct
from typing import Dict

import numpy as np

MAGIC = b"FXT1"
DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}
DTYPES_INV = {0: np.float32, 1: np.int32}


def write(path: str, tensors: Dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors.items():
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in DTYPES:
                if np.issubdtype(arr.dtype, np.floating):
                    arr = arr.astype(np.float32)
                elif np.issubdtype(arr.dtype, np.integer):
                    arr = arr.astype(np.int32)
                else:
                    raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", DTYPES[arr.dtype], arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.astype(arr.dtype.newbyteorder("<")).tobytes())


def read(path: str) -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            dt, nd = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{nd}I", f.read(4 * nd)) if nd else ()
            dtype = np.dtype(DTYPES_INV[dt]).newbyteorder("<")
            n = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(n * 4), dtype=dtype, count=n)
            out[name] = data.astype(DTYPES_INV[dt]).reshape(dims)
    return out
